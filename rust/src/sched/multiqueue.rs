//! The Multiqueue relaxed scheduler (Rihani–Sanders–Dementiev 2015;
//! Alistarh et al. 2017) — the paper's scheduling engine.
//!
//! `m = c·p` sequential binary heaps, each behind its own lock:
//!
//! - **Insert**: push into a uniformly random heap (try-lock with random
//!   retry, so contended inserts migrate to free queues).
//! - **ApproxDeleteMin**: read the *cached top priority* of two uniformly
//!   random heaps without locking, lock the one with the higher top, and
//!   pop it (re-checking under the lock).
//!
//! With `m ≥ 3` queues this classic two-choice strategy gives rank and
//! fairness guarantees `q = O(p log p)` w.h.p. [Alistarh et al., PODC'17].
//! The cached tops (one relaxed atomic per heap, updated under that heap's
//! lock) keep the common path to two atomic loads + one lock.

use super::{Entry, Scheduler};
use crate::util::{AtomicF64, CachePadded, Xoshiro256};
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

struct SubQueue {
    heap: Mutex<BinaryHeap<Entry>>,
    /// Priority of the heap's current top; `NEG_INFINITY` when empty.
    /// Written only under `heap`'s lock, read lock-free by `pop`.
    top: AtomicF64,
}

impl SubQueue {
    fn new() -> Self {
        SubQueue {
            heap: Mutex::new(BinaryHeap::new()),
            top: AtomicF64::new(f64::NEG_INFINITY),
        }
    }
}

/// The paper's relaxed Multiqueue: `c·p` sloppy heaps, two-choice pops.
pub struct Multiqueue {
    queues: Vec<CachePadded<SubQueue>>,
    len: AtomicUsize,
    /// Insert try-lock attempts before falling back to a blocking lock.
    insert_tries: usize,
}

impl Multiqueue {
    /// `m` independent heaps; the paper uses `m = 4 × threads`.
    pub fn new(m: usize) -> Self {
        assert!(m >= 1);
        let mut queues = Vec::with_capacity(m);
        queues.resize_with(m, || CachePadded(SubQueue::new()));
        Multiqueue { queues, len: AtomicUsize::new(0), insert_tries: 4 }
    }

    /// Convenience: `c` queues per thread for `p` threads (min 2 total so
    /// the two-choice pop has two targets).
    pub fn for_threads(p: usize, c: usize) -> Self {
        Self::new((p * c).max(2))
    }

    /// Number of internal heaps.
    pub fn num_queues(&self) -> usize {
        self.queues.len()
    }

    #[inline]
    fn push_locked(q: &SubQueue, heap: &mut BinaryHeap<Entry>, entry: Entry) {
        heap.push(entry);
        q.top.store(heap.peek().map_or(f64::NEG_INFINITY, |e| e.prio));
    }

    #[inline]
    fn pop_locked(q: &SubQueue, heap: &mut BinaryHeap<Entry>) -> Option<Entry> {
        let e = heap.pop();
        q.top.store(heap.peek().map_or(f64::NEG_INFINITY, |e| e.prio));
        e
    }
}

impl Scheduler for Multiqueue {
    fn insert(&self, entry: Entry, rng: &mut Xoshiro256) {
        let m = self.queues.len();
        // Try-lock a few random queues; a busy queue means another thread is
        // mutating it, so go elsewhere instead of waiting.
        for _ in 0..self.insert_tries {
            let i = rng.index(m);
            if let Ok(mut heap) = self.queues[i].heap.try_lock() {
                Self::push_locked(&self.queues[i], &mut heap, entry);
                self.len.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        // Fall back to blocking on one random queue (no livelock).
        let i = rng.index(m);
        let mut heap = self.queues[i].heap.lock().unwrap();
        Self::push_locked(&self.queues[i], &mut heap, entry);
        self.len.fetch_add(1, Ordering::Relaxed);
    }

    fn pop(&self, rng: &mut Xoshiro256) -> Option<Entry> {
        let m = self.queues.len();
        // A few two-choice attempts; on repeated failure do one full scan so
        // that "None" reliably means the queues were (momentarily) empty.
        for _ in 0..4 {
            let i = rng.index(m);
            let mut j = rng.index(m);
            if m > 1 {
                while j == i {
                    j = rng.index(m);
                }
            }
            let ti = self.queues[i].top.load();
            let tj = self.queues[j].top.load();
            let best = if ti >= tj { i } else { j };
            if self.queues[best].top.load() == f64::NEG_INFINITY {
                continue;
            }
            if let Ok(mut heap) = self.queues[best].heap.try_lock() {
                if let Some(e) = Self::pop_locked(&self.queues[best], &mut heap) {
                    self.len.fetch_sub(1, Ordering::Relaxed);
                    return Some(e);
                }
            }
        }
        // Full sweep (blocking locks) — guarantees progress when few
        // entries remain.
        for i in 0..m {
            let mut heap = self.queues[i].heap.lock().unwrap();
            if let Some(e) = Self::pop_locked(&self.queues[i], &mut heap) {
                self.len.fetch_sub(1, Ordering::Relaxed);
                return Some(e);
            }
        }
        None
    }

    fn approx_len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256 {
        Xoshiro256::seed_from_u64(7)
    }

    #[test]
    fn pop_returns_all_inserted() {
        let q = Multiqueue::new(8);
        let mut r = rng();
        for t in 0..1000u32 {
            q.insert(Entry { prio: r.next_f64(), task: t, epoch: 0 }, &mut r);
        }
        assert_eq!(q.approx_len(), 1000);
        let mut seen = std::collections::HashSet::new();
        while let Some(e) = q.pop(&mut r) {
            assert!(seen.insert(e.task));
        }
        assert_eq!(seen.len(), 1000);
        assert_eq!(q.approx_len(), 0);
        assert!(q.pop(&mut r).is_none());
    }

    #[test]
    fn rank_is_relaxed_but_bounded_in_practice() {
        // Insert n entries with distinct priorities; pop all; measure the
        // rank error of each pop (how many higher-priority entries were
        // still queued). With two-choice over m=8 queues the mean rank
        // error should be far below n.
        let n = 2000u32;
        let q = Multiqueue::new(8);
        let mut r = rng();
        for t in 0..n {
            q.insert(Entry { prio: t as f64, task: t, epoch: 0 }, &mut r);
        }
        let mut live: std::collections::BTreeSet<u32> = (0..n).collect();
        let mut total_rank = 0usize;
        let mut max_rank = 0usize;
        while let Some(e) = q.pop(&mut r) {
            // rank = number of live entries with higher priority
            let rank = live.range(e.task + 1..).count();
            total_rank += rank;
            max_rank = max_rank.max(rank);
            live.remove(&e.task);
        }
        assert!(live.is_empty());
        let mean = total_rank as f64 / n as f64;
        assert!(mean < 32.0, "mean rank error {mean} too high for m=8");
        assert!(max_rank < n as usize / 4, "max rank error {max_rank}");
    }

    #[test]
    fn single_queue_is_exact() {
        // m=1 degenerates to an exact queue (both choices hit the same heap).
        let q = Multiqueue::new(1);
        let mut r = rng();
        for (i, p) in [0.2, 0.8, 0.5].iter().enumerate() {
            q.insert(Entry { prio: *p, task: i as u32, epoch: 0 }, &mut r);
        }
        let order: Vec<f64> = std::iter::from_fn(|| q.pop(&mut r)).map(|e| e.prio).collect();
        assert_eq!(order, vec![0.8, 0.5, 0.2]);
    }

    #[test]
    fn concurrent_producers_consumers() {
        let q = std::sync::Arc::new(Multiqueue::for_threads(4, 4));
        let per = 2000u32;
        let popped = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let q = std::sync::Arc::clone(&q);
                s.spawn(move || {
                    let mut r = Xoshiro256::stream(3, t);
                    for i in 0..per {
                        q.insert(
                            Entry { prio: r.next_f64(), task: t as u32 * per + i, epoch: 0 },
                            &mut r,
                        );
                    }
                });
            }
            for t in 0..2u64 {
                let q = std::sync::Arc::clone(&q);
                let popped = std::sync::Arc::clone(&popped);
                s.spawn(move || {
                    let mut r = Xoshiro256::stream(11, t);
                    let mut local = Vec::new();
                    // Consume until we've seen nothing for a while.
                    let mut misses = 0;
                    while misses < 100 {
                        match q.pop(&mut r) {
                            Some(e) => {
                                local.push(e.task);
                                misses = 0;
                            }
                            None => {
                                misses += 1;
                                std::thread::yield_now();
                            }
                        }
                    }
                    popped.lock().unwrap().extend(local);
                });
            }
        });
        let mut all = popped.lock().unwrap().clone();
        let mut r = rng();
        while let Some(e) = q.pop(&mut r) {
            all.push(e.task);
        }
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 4 * per as usize, "no lost or duplicated entries");
    }

    #[test]
    fn for_threads_minimum_two() {
        let q = Multiqueue::for_threads(1, 1);
        assert_eq!(q.num_queues(), 2);
    }
}
