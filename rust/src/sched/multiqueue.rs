//! The Multiqueue relaxed scheduler (Rihani–Sanders–Dementiev 2015;
//! Alistarh et al. 2017) — the paper's scheduling engine.
//!
//! `m = c·p` sequential binary heaps, each behind its own lock:
//!
//! - **Insert**: push into a uniformly random heap (try-lock with random
//!   retry, so contended inserts migrate to free queues).
//! - **ApproxDeleteMin**: read the *cached top priority* of two uniformly
//!   random heaps without locking, lock the one with the higher top, and
//!   pop it (re-checking under the lock).
//!
//! With `m ≥ 3` queues this classic two-choice strategy gives rank and
//! fairness guarantees `q = O(p log p)` w.h.p. [Alistarh et al., PODC'17].
//! The cached tops (one relaxed atomic per heap, updated under that heap's
//! lock) keep the common path to two atomic loads + one lock.
//!
//! ## Shard-affine mode
//!
//! [`Multiqueue::shard_affine`] splits the heaps into one **queue group
//! per shard** of the run's [`Partition`](crate::model::Partition)
//! (contiguous, ≥ 2 heaps each so two-choice stays meaningful). Operations
//! carrying a shard hint ([`Scheduler::insert_hint`] /
//! [`Scheduler::pop_hint`]) stay inside the hinted group with probability
//! `1 − spill` and take the classic global path with probability `spill` —
//! the knob that trades cache locality against cross-shard priority
//! mixing. The entry/epoch/claim protocol is untouched: a pop that finds
//! the local group empty still falls back to the global blocking sweep, so
//! `pop → None` means the *whole* structure was momentarily empty exactly
//! as in the blind mode (which the quiescence accounting relies on).
//!
//! ## Batched operations
//!
//! [`Scheduler::insert_batch`] places a whole batch (one node's refreshed
//! out-edges, from the fused update kernel) on a single randomly chosen
//! sub-queue — one RNG draw and one lock acquisition per batch instead of
//! per entry. [`Scheduler::pop_batch`] performs the two-choice selection
//! once and drains up to `max` entries under that one lock, falling back
//! to the global blocking sweep on repeated failure so that a return of 0
//! keeps meaning "momentarily empty". Both are pure amortizations: entry
//! multisets, the epoch/claim protocol, and quiescence accounting are
//! untouched; only the rank relaxation is slightly coarser (a batch
//! shares one heap), the classic batched-MultiQueue trade.

use super::{Entry, Scheduler};
use crate::util::{AtomicF64, CachePadded, Xoshiro256};
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

struct SubQueue {
    heap: Mutex<BinaryHeap<Entry>>,
    /// Priority of the heap's current top; `NEG_INFINITY` when empty.
    /// Written only under `heap`'s lock, read lock-free by `pop`.
    top: AtomicF64,
}

impl SubQueue {
    fn new() -> Self {
        SubQueue {
            heap: Mutex::new(BinaryHeap::new()),
            top: AtomicF64::new(f64::NEG_INFINITY),
        }
    }
}

/// Queue-group ownership for the shard-affine mode.
struct Affinity {
    /// Group `g` owns queues `bounds[g]..bounds[g+1]` (each nonempty).
    bounds: Vec<u32>,
    /// Probability that a hinted operation takes the global path.
    spill: f64,
}

impl Affinity {
    /// Queue range owned by `shard` (shards beyond the group count wrap —
    /// defensive; the pool builds both from the same partition).
    #[inline]
    fn range(&self, shard: u32) -> (usize, usize) {
        let g = shard as usize % (self.bounds.len() - 1);
        (self.bounds[g] as usize, self.bounds[g + 1] as usize)
    }
}

/// The paper's relaxed Multiqueue: `c·p` sloppy heaps, two-choice pops;
/// optionally shard-affine (see the module docs).
pub struct Multiqueue {
    queues: Vec<CachePadded<SubQueue>>,
    len: AtomicUsize,
    /// Insert try-lock attempts before falling back to a blocking lock.
    insert_tries: usize,
    /// Shard-affine queue grouping; `None` = the classic blind Multiqueue.
    affinity: Option<Affinity>,
}

impl Multiqueue {
    /// `m` independent heaps; the paper uses `m = 4 × threads`.
    pub fn new(m: usize) -> Self {
        assert!(m >= 1);
        let mut queues = Vec::with_capacity(m);
        queues.resize_with(m, || CachePadded(SubQueue::new()));
        Multiqueue { queues, len: AtomicUsize::new(0), insert_tries: 4, affinity: None }
    }

    /// Convenience: `c` queues per thread for `p` threads (min 2 total so
    /// the two-choice pop has two targets).
    pub fn for_threads(p: usize, c: usize) -> Self {
        Self::new((p * c).max(2))
    }

    /// Shard-affine Multiqueue for `p` threads × `c` queues each over
    /// `shards` task shards: at least two heaps per shard group, hinted
    /// operations spill to the global path with probability `spill`.
    pub fn shard_affine(p: usize, c: usize, shards: usize, spill: f64) -> Self {
        let shards = shards.max(1);
        let m = (p * c).max(2).max(2 * shards);
        let mut q = Multiqueue::new(m);
        let mut bounds = Vec::with_capacity(shards + 1);
        for g in 0..=shards {
            bounds.push((g * m / shards) as u32);
        }
        q.affinity = Some(Affinity { bounds, spill: spill.clamp(0.0, 1.0) });
        q
    }

    /// Number of internal heaps.
    pub fn num_queues(&self) -> usize {
        self.queues.len()
    }

    /// Number of shard groups (1 when not shard-affine).
    pub fn num_shard_groups(&self) -> usize {
        self.affinity.as_ref().map_or(1, |a| a.bounds.len() - 1)
    }

    #[inline]
    fn pop_locked(q: &SubQueue, heap: &mut BinaryHeap<Entry>) -> Option<Entry> {
        let e = heap.pop();
        q.top.store(heap.peek().map_or(f64::NEG_INFINITY, |e| e.prio));
        e
    }

    /// Insert into a random queue of `[lo, hi)` (try-lock with random
    /// retry, then one blocking lock — no livelock).
    fn insert_in(&self, entry: Entry, rng: &mut Xoshiro256, lo: usize, hi: usize) {
        self.insert_all_in(std::slice::from_ref(&entry), rng, lo, hi);
    }

    /// Insert a whole batch into ONE random queue of `[lo, hi)` — a single
    /// RNG draw and a single lock acquisition amortized over the batch
    /// (try-lock with random retry, then one blocking lock — no livelock).
    fn insert_all_in(&self, entries: &[Entry], rng: &mut Xoshiro256, lo: usize, hi: usize) {
        let w = hi - lo;
        // Try-lock a few random queues; a busy queue means another thread is
        // mutating it, so go elsewhere instead of waiting.
        for _ in 0..self.insert_tries {
            let i = lo + rng.index(w);
            if let Ok(mut heap) = self.queues[i].heap.try_lock() {
                Self::push_all_locked(&self.queues[i], &mut heap, entries);
                self.len.fetch_add(entries.len(), Ordering::Relaxed);
                return;
            }
        }
        // Fall back to blocking on one random queue (no livelock).
        let i = lo + rng.index(w);
        let mut heap = self.queues[i].heap.lock().unwrap();
        Self::push_all_locked(&self.queues[i], &mut heap, entries);
        self.len.fetch_add(entries.len(), Ordering::Relaxed);
    }

    #[inline]
    fn push_all_locked(q: &SubQueue, heap: &mut BinaryHeap<Entry>, entries: &[Entry]) {
        for &e in entries {
            heap.push(e);
        }
        q.top.store(heap.peek().map_or(f64::NEG_INFINITY, |e| e.prio));
    }

    /// One two-choice pop attempt over `[lo, hi)`: compare the cached tops
    /// of two random queues, try-lock the better one.
    fn try_pop_two_choice(&self, rng: &mut Xoshiro256, lo: usize, hi: usize) -> Option<Entry> {
        let w = hi - lo;
        let i = lo + rng.index(w);
        let mut j = lo + rng.index(w);
        if w > 1 {
            while j == i {
                j = lo + rng.index(w);
            }
        }
        let ti = self.queues[i].top.load();
        let tj = self.queues[j].top.load();
        let best = if ti >= tj { i } else { j };
        if self.queues[best].top.load() == f64::NEG_INFINITY {
            return None;
        }
        if let Ok(mut heap) = self.queues[best].heap.try_lock() {
            if let Some(e) = Self::pop_locked(&self.queues[best], &mut heap) {
                self.len.fetch_sub(1, Ordering::Relaxed);
                return Some(e);
            }
        }
        None
    }

    /// Full sweep with blocking locks — guarantees progress when few
    /// entries remain, and makes `None` reliably mean "(momentarily)
    /// empty" across every queue, local or not.
    fn sweep_pop(&self) -> Option<Entry> {
        for i in 0..self.queues.len() {
            let mut heap = self.queues[i].heap.lock().unwrap();
            if let Some(e) = Self::pop_locked(&self.queues[i], &mut heap) {
                self.len.fetch_sub(1, Ordering::Relaxed);
                return Some(e);
            }
        }
        None
    }
}

impl Scheduler for Multiqueue {
    fn insert(&self, entry: Entry, rng: &mut Xoshiro256) {
        self.insert_in(entry, rng, 0, self.queues.len());
    }

    fn pop(&self, rng: &mut Xoshiro256) -> Option<Entry> {
        // A few two-choice attempts; on repeated failure do one full scan so
        // that "None" reliably means the queues were (momentarily) empty.
        for _ in 0..4 {
            if let Some(e) = self.try_pop_two_choice(rng, 0, self.queues.len()) {
                return Some(e);
            }
        }
        self.sweep_pop()
    }

    fn insert_hint(&self, entry: Entry, rng: &mut Xoshiro256, shard: Option<u32>) {
        match (&self.affinity, shard) {
            (Some(a), Some(s)) if !rng.bernoulli(a.spill) => {
                let (lo, hi) = a.range(s);
                self.insert_in(entry, rng, lo, hi);
            }
            _ => self.insert(entry, rng),
        }
    }

    fn pop_hint(&self, rng: &mut Xoshiro256, shard: Option<u32>) -> Option<Entry> {
        let (Some(a), Some(s)) = (&self.affinity, shard) else {
            return self.pop(rng);
        };
        let (lo, hi) = a.range(s);
        for _ in 0..4 {
            let (lo, hi) =
                if rng.bernoulli(a.spill) { (0, self.queues.len()) } else { (lo, hi) };
            if let Some(e) = self.try_pop_two_choice(rng, lo, hi) {
                return Some(e);
            }
        }
        // Local group (momentarily) empty: steal globally so liveness and
        // the "None ⟺ all queues empty" contract match the blind mode.
        self.sweep_pop()
    }

    /// One RNG draw + one lock acquisition for the whole batch: every
    /// entry lands on the same (randomly chosen, shard-hinted) sub-queue.
    /// Concentrating one node's refreshed out-edges on one heap is the
    /// batched-MultiQueue trade — slightly coarser rank guarantees for a
    /// per-entry scheduler cost that no longer scales with node degree.
    fn insert_batch(&self, entries: &[Entry], rng: &mut Xoshiro256, shard: Option<u32>) {
        if entries.is_empty() {
            return;
        }
        match (&self.affinity, shard) {
            (Some(a), Some(s)) if !rng.bernoulli(a.spill) => {
                let (lo, hi) = a.range(s);
                self.insert_all_in(entries, rng, lo, hi);
            }
            _ => self.insert_all_in(entries, rng, 0, self.queues.len()),
        }
    }

    /// Two-choice queue selection once per sub-queue visit, then drain up
    /// to `max` entries under that single lock. Falls back to the global
    /// blocking sweep exactly like [`Multiqueue::pop`], so a return of 0
    /// still means the whole structure was momentarily empty.
    fn pop_batch(
        &self,
        rng: &mut Xoshiro256,
        shard: Option<u32>,
        max: usize,
        out: &mut Vec<Entry>,
    ) -> usize {
        if max == 0 {
            return 0;
        }
        for _ in 0..4 {
            let (lo, hi) = match (&self.affinity, shard) {
                (Some(a), Some(s)) if !rng.bernoulli(a.spill) => a.range(s),
                _ => (0, self.queues.len()),
            };
            let w = hi - lo;
            let i = lo + rng.index(w);
            let mut j = lo + rng.index(w);
            if w > 1 {
                while j == i {
                    j = lo + rng.index(w);
                }
            }
            let best = if self.queues[i].top.load() >= self.queues[j].top.load() { i } else { j };
            if self.queues[best].top.load() == f64::NEG_INFINITY {
                continue;
            }
            if let Ok(mut heap) = self.queues[best].heap.try_lock() {
                let mut popped = 0;
                while popped < max {
                    match heap.pop() {
                        Some(e) => {
                            out.push(e);
                            popped += 1;
                        }
                        None => break,
                    }
                }
                self.queues[best]
                    .top
                    .store(heap.peek().map_or(f64::NEG_INFINITY, |e| e.prio));
                if popped > 0 {
                    self.len.fetch_sub(popped, Ordering::Relaxed);
                    return popped;
                }
            }
        }
        // Repeated two-choice failure: one blocking sweep so that 0
        // reliably means "(momentarily) empty", as the quiescence
        // accounting requires.
        match self.sweep_pop() {
            Some(e) => {
                out.push(e);
                1
            }
            None => 0,
        }
    }

    fn approx_len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }

    /// Lock every sub-queue in turn and report true only if each heap was
    /// empty when visited. The relaxed `len` counter can transiently
    /// disagree with the heaps (it is updated outside the heap locks), so
    /// the termination path must not trust `approx_len` alone: an entry
    /// whose insert completed before this call is guaranteed to be seen,
    /// which is the property the distributed token ring needs.
    fn is_definitely_empty(&self) -> bool {
        self.queues.iter().all(|q| q.heap.lock().unwrap().is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256 {
        Xoshiro256::seed_from_u64(7)
    }

    #[test]
    fn pop_returns_all_inserted() {
        let q = Multiqueue::new(8);
        let mut r = rng();
        for t in 0..1000u32 {
            q.insert(Entry { prio: r.next_f64(), task: t, epoch: 0 }, &mut r);
        }
        assert_eq!(q.approx_len(), 1000);
        let mut seen = std::collections::HashSet::new();
        while let Some(e) = q.pop(&mut r) {
            assert!(seen.insert(e.task));
        }
        assert_eq!(seen.len(), 1000);
        assert_eq!(q.approx_len(), 0);
        assert!(q.pop(&mut r).is_none());
    }

    #[test]
    fn rank_is_relaxed_but_bounded_in_practice() {
        // Insert n entries with distinct priorities; pop all; measure the
        // rank error of each pop (how many higher-priority entries were
        // still queued). With two-choice over m=8 queues the mean rank
        // error should be far below n.
        let n = 2000u32;
        let q = Multiqueue::new(8);
        let mut r = rng();
        for t in 0..n {
            q.insert(Entry { prio: t as f64, task: t, epoch: 0 }, &mut r);
        }
        let mut live: std::collections::BTreeSet<u32> = (0..n).collect();
        let mut total_rank = 0usize;
        let mut max_rank = 0usize;
        while let Some(e) = q.pop(&mut r) {
            // rank = number of live entries with higher priority
            let rank = live.range(e.task + 1..).count();
            total_rank += rank;
            max_rank = max_rank.max(rank);
            live.remove(&e.task);
        }
        assert!(live.is_empty());
        let mean = total_rank as f64 / n as f64;
        assert!(mean < 32.0, "mean rank error {mean} too high for m=8");
        assert!(max_rank < n as usize / 4, "max rank error {max_rank}");
    }

    #[test]
    fn single_queue_is_exact() {
        // m=1 degenerates to an exact queue (both choices hit the same heap).
        let q = Multiqueue::new(1);
        let mut r = rng();
        for (i, p) in [0.2, 0.8, 0.5].iter().enumerate() {
            q.insert(Entry { prio: *p, task: i as u32, epoch: 0 }, &mut r);
        }
        let order: Vec<f64> = std::iter::from_fn(|| q.pop(&mut r)).map(|e| e.prio).collect();
        assert_eq!(order, vec![0.8, 0.5, 0.2]);
    }

    #[test]
    fn concurrent_producers_consumers() {
        let q = std::sync::Arc::new(Multiqueue::for_threads(4, 4));
        let per = 2000u32;
        let popped = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let q = std::sync::Arc::clone(&q);
                s.spawn(move || {
                    let mut r = Xoshiro256::stream(3, t);
                    for i in 0..per {
                        q.insert(
                            Entry { prio: r.next_f64(), task: t as u32 * per + i, epoch: 0 },
                            &mut r,
                        );
                    }
                });
            }
            for t in 0..2u64 {
                let q = std::sync::Arc::clone(&q);
                let popped = std::sync::Arc::clone(&popped);
                s.spawn(move || {
                    let mut r = Xoshiro256::stream(11, t);
                    let mut local = Vec::new();
                    // Consume until we've seen nothing for a while.
                    let mut misses = 0;
                    while misses < 100 {
                        match q.pop(&mut r) {
                            Some(e) => {
                                local.push(e.task);
                                misses = 0;
                            }
                            None => {
                                misses += 1;
                                std::thread::yield_now();
                            }
                        }
                    }
                    popped.lock().unwrap().extend(local);
                });
            }
        });
        let mut all = popped.lock().unwrap().clone();
        let mut r = rng();
        while let Some(e) = q.pop(&mut r) {
            all.push(e.task);
        }
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 4 * per as usize, "no lost or duplicated entries");
    }

    #[test]
    fn for_threads_minimum_two() {
        let q = Multiqueue::for_threads(1, 1);
        assert_eq!(q.num_queues(), 2);
    }

    #[test]
    fn shard_affine_geometry() {
        // Each shard group gets at least two heaps even when p·c is small.
        let q = Multiqueue::shard_affine(1, 1, 7, 0.1);
        assert_eq!(q.num_shard_groups(), 7);
        assert!(q.num_queues() >= 14);
        let q = Multiqueue::shard_affine(4, 4, 2, 0.1);
        assert_eq!(q.num_queues(), 16);
        assert_eq!(q.num_shard_groups(), 2);
    }

    #[test]
    fn shard_affine_preserves_multiset() {
        // No entry is lost or duplicated under hinted inserts and pops,
        // regardless of shard routing or spill.
        for spill in [0.0, 0.25, 1.0] {
            let q = Multiqueue::shard_affine(2, 4, 4, spill);
            let mut r = rng();
            for t in 0..1000u32 {
                q.insert_hint(Entry { prio: r.next_f64(), task: t, epoch: 0 }, &mut r, Some(t % 4));
            }
            assert_eq!(q.approx_len(), 1000);
            let mut seen = std::collections::HashSet::new();
            let mut home = 0u32;
            while let Some(e) = q.pop_hint(&mut r, Some(home)) {
                assert!(seen.insert(e.task));
                home = (home + 1) % 4;
            }
            assert_eq!(seen.len(), 1000, "spill={spill}");
            assert_eq!(q.approx_len(), 0);
        }
    }

    #[test]
    fn zero_spill_keeps_entries_shard_local() {
        // With spill = 0, an entry inserted for shard s is always popped by
        // a worker hinting s before workers of other shards can see it via
        // two-choice (they can only reach it through the fallback sweep,
        // which this test never triggers because shard 0 stays nonempty).
        let q = Multiqueue::shard_affine(2, 4, 2, 0.0);
        let mut r = rng();
        for t in 0..100u32 {
            q.insert_hint(Entry { prio: t as f64, task: t, epoch: 0 }, &mut r, Some(0));
        }
        // Popping with the shard-0 hint drains everything without the
        // global sweep; the shard-1 group never held an entry.
        let mut popped = 0;
        while let Some(_e) = q.pop_hint(&mut r, Some(0)) {
            popped += 1;
        }
        assert_eq!(popped, 100);
    }

    #[test]
    fn batch_ops_preserve_multiset_blind() {
        let q = Multiqueue::new(8);
        let mut r = rng();
        // Insert 300 entries in batches of 7.
        let mut next = 0u32;
        while next < 300 {
            let batch: Vec<Entry> = (0..7.min(300 - next))
                .map(|k| Entry { prio: r.next_f64(), task: next + k, epoch: 0 })
                .collect();
            next += batch.len() as u32;
            q.insert_batch(&batch, &mut r, None);
        }
        assert_eq!(q.approx_len(), 300);
        let mut seen = std::collections::HashSet::new();
        let mut buf = Vec::new();
        loop {
            buf.clear();
            let n = q.pop_batch(&mut r, None, 5, &mut buf);
            assert_eq!(n, buf.len());
            assert!(n <= 5, "pop_batch respects max");
            if n == 0 {
                break;
            }
            for e in &buf {
                assert!(seen.insert(e.task), "duplicate {}", e.task);
            }
        }
        assert_eq!(seen.len(), 300);
        assert_eq!(q.approx_len(), 0);
    }

    #[test]
    fn batch_ops_preserve_multiset_shard_affine() {
        for spill in [0.0, 0.25, 1.0] {
            let q = Multiqueue::shard_affine(2, 4, 4, spill);
            let mut r = rng();
            for b in 0..100u32 {
                let batch: Vec<Entry> = (0..10)
                    .map(|k| Entry { prio: r.next_f64(), task: b * 10 + k, epoch: 0 })
                    .collect();
                q.insert_batch(&batch, &mut r, Some(b % 4));
            }
            assert_eq!(q.approx_len(), 1000);
            let mut seen = std::collections::HashSet::new();
            let mut buf = Vec::new();
            let mut home = 0u32;
            loop {
                buf.clear();
                if q.pop_batch(&mut r, Some(home), 8, &mut buf) == 0 {
                    break;
                }
                for e in &buf {
                    assert!(seen.insert(e.task));
                }
                home = (home + 1) % 4;
            }
            assert_eq!(seen.len(), 1000, "spill={spill}");
            assert_eq!(q.approx_len(), 0);
        }
    }

    #[test]
    fn pop_batch_single_queue_is_priority_ordered() {
        // m=1: batched pops drain the lone heap in exact priority order.
        let q = Multiqueue::new(1);
        let mut r = rng();
        let batch: Vec<Entry> = [0.2, 0.9, 0.5]
            .iter()
            .enumerate()
            .map(|(i, &p)| Entry { prio: p, task: i as u32, epoch: 0 })
            .collect();
        q.insert_batch(&batch, &mut r, None);
        let mut buf = Vec::new();
        assert_eq!(q.pop_batch(&mut r, None, 8, &mut buf), 3);
        let prios: Vec<f64> = buf.iter().map(|e| e.prio).collect();
        assert_eq!(prios, vec![0.9, 0.5, 0.2]);
        assert_eq!(q.pop_batch(&mut r, None, 8, &mut buf), 0, "empty → 0");
    }

    #[test]
    fn empty_insert_batch_is_noop() {
        let q = Multiqueue::new(4);
        let mut r = rng();
        q.insert_batch(&[], &mut r, None);
        assert_eq!(q.approx_len(), 0);
        assert!(q.pop(&mut r).is_none());
    }

    #[test]
    fn default_batch_impls_on_exact_queue() {
        // ExactQueue uses the trait's default per-entry delegation.
        use crate::sched::ExactQueue;
        let q = ExactQueue::new();
        let mut r = rng();
        let batch: Vec<Entry> = (0..10)
            .map(|t| Entry { prio: t as f64, task: t, epoch: 0 })
            .collect();
        q.insert_batch(&batch, &mut r, None);
        let mut buf = Vec::new();
        assert_eq!(q.pop_batch(&mut r, None, 4, &mut buf), 4);
        let tasks: Vec<u32> = buf.iter().map(|e| e.task).collect();
        assert_eq!(tasks, vec![9, 8, 7, 6], "exact queue pops best-first");
        assert_eq!(q.pop_batch(&mut r, None, 100, &mut buf), 6);
    }

    #[test]
    fn definitely_empty_tracks_heaps_not_counter() {
        let q = Multiqueue::new(8);
        let mut r = rng();
        assert!(q.is_definitely_empty());
        q.insert(Entry { prio: 1.0, task: 0, epoch: 0 }, &mut r);
        assert!(!q.is_definitely_empty());
        q.pop(&mut r).unwrap();
        assert!(q.is_definitely_empty());
    }

    #[test]
    fn definitely_empty_sees_slow_inserter_entries() {
        // Race a sweeper against an inserter that trickles entries in with
        // deliberate pauses: whenever the sweeper observes "definitely
        // empty", every entry whose insert had *completed* must already
        // have been popped — is_definitely_empty must never report empty
        // while a fully inserted entry is still sitting in some heap (the
        // false positive a momentarily-unlucky pop sample could produce).
        use std::sync::atomic::{AtomicUsize, Ordering};
        let q = std::sync::Arc::new(Multiqueue::for_threads(4, 4));
        let inserted = std::sync::Arc::new(AtomicUsize::new(0));
        let total = 300u32;
        std::thread::scope(|s| {
            {
                let q = std::sync::Arc::clone(&q);
                let inserted = std::sync::Arc::clone(&inserted);
                s.spawn(move || {
                    let mut r = Xoshiro256::stream(5, 1);
                    for t in 0..total {
                        q.insert(Entry { prio: r.next_f64(), task: t, epoch: 0 }, &mut r);
                        inserted.fetch_add(1, Ordering::Release);
                        if t % 16 == 0 {
                            // Stall with the structure nonempty so the
                            // sweeper gets plenty of mid-stream looks.
                            std::thread::sleep(std::time::Duration::from_micros(50));
                        }
                    }
                });
            }
            let mut r = Xoshiro256::stream(5, 2);
            let mut popped = 0usize;
            loop {
                if let Some(_e) = q.pop(&mut r) {
                    popped += 1;
                    continue;
                }
                // Snapshot completed inserts BEFORE the sweep: those
                // entries were fully in some heap when the sweep began, so
                // an "empty" verdict proves this thread (the only popper)
                // already drained every one of them.
                let done = inserted.load(Ordering::Acquire);
                if q.is_definitely_empty() {
                    assert!(
                        popped >= done,
                        "definitely-empty with {done} inserted but only {popped} popped"
                    );
                    if popped == total as usize {
                        break;
                    }
                }
                std::thread::yield_now();
            }
            assert_eq!(popped, total as usize);
        });
    }

    #[test]
    fn hint_on_blind_queue_is_ignored() {
        let q = Multiqueue::new(4);
        let mut r = rng();
        q.insert_hint(Entry { prio: 1.0, task: 0, epoch: 0 }, &mut r, Some(3));
        assert_eq!(q.pop_hint(&mut r, Some(1)).unwrap().task, 0);
    }

    #[test]
    fn cross_shard_steal_via_sweep() {
        // A worker whose home shard is empty must still drain other
        // shards' entries (the liveness half of the affinity contract).
        let q = Multiqueue::shard_affine(2, 4, 2, 0.0);
        let mut r = rng();
        q.insert_hint(Entry { prio: 1.0, task: 7, epoch: 0 }, &mut r, Some(1));
        let e = q.pop_hint(&mut r, Some(0)).expect("steals from shard 1");
        assert_eq!(e.task, 7);
        assert!(q.pop_hint(&mut r, Some(0)).is_none());
    }

    #[test]
    fn shard_affine_concurrent_producers_consumers() {
        let q = std::sync::Arc::new(Multiqueue::shard_affine(4, 4, 4, 0.1));
        let per = 1000u32;
        let popped = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let q = std::sync::Arc::clone(&q);
                s.spawn(move || {
                    let mut r = Xoshiro256::stream(21, t);
                    for i in 0..per {
                        let task = t as u32 * per + i;
                        q.insert_hint(
                            Entry { prio: r.next_f64(), task, epoch: 0 },
                            &mut r,
                            Some(task % 4),
                        );
                    }
                });
            }
            for t in 0..2u64 {
                let q = std::sync::Arc::clone(&q);
                let popped = std::sync::Arc::clone(&popped);
                s.spawn(move || {
                    let mut r = Xoshiro256::stream(31, t);
                    let mut local = Vec::new();
                    let mut misses = 0;
                    while misses < 100 {
                        match q.pop_hint(&mut r, Some(t as u32)) {
                            Some(e) => {
                                local.push(e.task);
                                misses = 0;
                            }
                            None => {
                                misses += 1;
                                std::thread::yield_now();
                            }
                        }
                    }
                    popped.lock().unwrap().extend(local);
                });
            }
        });
        let mut all = popped.lock().unwrap().clone();
        let mut r = rng();
        while let Some(e) = q.pop_hint(&mut r, Some(0)) {
            all.push(e.task);
        }
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 4 * per as usize, "no lost or duplicated entries");
    }
}
