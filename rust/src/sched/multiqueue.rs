//! The Multiqueue relaxed scheduler (Rihani–Sanders–Dementiev 2015;
//! Alistarh et al. 2017) — the paper's scheduling engine.
//!
//! `m = c·p` sequential binary heaps, each behind its own lock:
//!
//! - **Insert**: push into a uniformly random heap (try-lock with random
//!   retry, so contended inserts migrate to free queues).
//! - **ApproxDeleteMin**: read the *cached top priority* of two uniformly
//!   random heaps without locking, lock the one with the higher top, and
//!   pop it (re-checking under the lock).
//!
//! With `m ≥ 3` queues this classic two-choice strategy gives rank and
//! fairness guarantees `q = O(p log p)` w.h.p. [Alistarh et al., PODC'17].
//! The cached tops (one relaxed atomic per heap, updated under that heap's
//! lock) keep the common path to two atomic loads + one lock.
//!
//! ## Shard-affine mode
//!
//! [`Multiqueue::shard_affine`] splits the heaps into one **queue group
//! per shard** of the run's [`Partition`](crate::model::Partition)
//! (contiguous, ≥ 2 heaps each so two-choice stays meaningful). Operations
//! carrying a shard hint ([`Scheduler::insert_hint`] /
//! [`Scheduler::pop_hint`]) stay inside the hinted group with probability
//! `1 − spill` and take the classic global path with probability `spill` —
//! the knob that trades cache locality against cross-shard priority
//! mixing. The entry/epoch/claim protocol is untouched: a pop that finds
//! the local group empty still falls back to the global blocking sweep, so
//! `pop → None` means the *whole* structure was momentarily empty exactly
//! as in the blind mode (which the quiescence accounting relies on).

use super::{Entry, Scheduler};
use crate::util::{AtomicF64, CachePadded, Xoshiro256};
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

struct SubQueue {
    heap: Mutex<BinaryHeap<Entry>>,
    /// Priority of the heap's current top; `NEG_INFINITY` when empty.
    /// Written only under `heap`'s lock, read lock-free by `pop`.
    top: AtomicF64,
}

impl SubQueue {
    fn new() -> Self {
        SubQueue {
            heap: Mutex::new(BinaryHeap::new()),
            top: AtomicF64::new(f64::NEG_INFINITY),
        }
    }
}

/// Queue-group ownership for the shard-affine mode.
struct Affinity {
    /// Group `g` owns queues `bounds[g]..bounds[g+1]` (each nonempty).
    bounds: Vec<u32>,
    /// Probability that a hinted operation takes the global path.
    spill: f64,
}

impl Affinity {
    /// Queue range owned by `shard` (shards beyond the group count wrap —
    /// defensive; the pool builds both from the same partition).
    #[inline]
    fn range(&self, shard: u32) -> (usize, usize) {
        let g = shard as usize % (self.bounds.len() - 1);
        (self.bounds[g] as usize, self.bounds[g + 1] as usize)
    }
}

/// The paper's relaxed Multiqueue: `c·p` sloppy heaps, two-choice pops;
/// optionally shard-affine (see the module docs).
pub struct Multiqueue {
    queues: Vec<CachePadded<SubQueue>>,
    len: AtomicUsize,
    /// Insert try-lock attempts before falling back to a blocking lock.
    insert_tries: usize,
    /// Shard-affine queue grouping; `None` = the classic blind Multiqueue.
    affinity: Option<Affinity>,
}

impl Multiqueue {
    /// `m` independent heaps; the paper uses `m = 4 × threads`.
    pub fn new(m: usize) -> Self {
        assert!(m >= 1);
        let mut queues = Vec::with_capacity(m);
        queues.resize_with(m, || CachePadded(SubQueue::new()));
        Multiqueue { queues, len: AtomicUsize::new(0), insert_tries: 4, affinity: None }
    }

    /// Convenience: `c` queues per thread for `p` threads (min 2 total so
    /// the two-choice pop has two targets).
    pub fn for_threads(p: usize, c: usize) -> Self {
        Self::new((p * c).max(2))
    }

    /// Shard-affine Multiqueue for `p` threads × `c` queues each over
    /// `shards` task shards: at least two heaps per shard group, hinted
    /// operations spill to the global path with probability `spill`.
    pub fn shard_affine(p: usize, c: usize, shards: usize, spill: f64) -> Self {
        let shards = shards.max(1);
        let m = (p * c).max(2).max(2 * shards);
        let mut q = Multiqueue::new(m);
        let mut bounds = Vec::with_capacity(shards + 1);
        for g in 0..=shards {
            bounds.push((g * m / shards) as u32);
        }
        q.affinity = Some(Affinity { bounds, spill: spill.clamp(0.0, 1.0) });
        q
    }

    /// Number of internal heaps.
    pub fn num_queues(&self) -> usize {
        self.queues.len()
    }

    /// Number of shard groups (1 when not shard-affine).
    pub fn num_shard_groups(&self) -> usize {
        self.affinity.as_ref().map_or(1, |a| a.bounds.len() - 1)
    }

    #[inline]
    fn push_locked(q: &SubQueue, heap: &mut BinaryHeap<Entry>, entry: Entry) {
        heap.push(entry);
        q.top.store(heap.peek().map_or(f64::NEG_INFINITY, |e| e.prio));
    }

    #[inline]
    fn pop_locked(q: &SubQueue, heap: &mut BinaryHeap<Entry>) -> Option<Entry> {
        let e = heap.pop();
        q.top.store(heap.peek().map_or(f64::NEG_INFINITY, |e| e.prio));
        e
    }

    /// Insert into a random queue of `[lo, hi)` (try-lock with random
    /// retry, then one blocking lock — no livelock).
    fn insert_in(&self, entry: Entry, rng: &mut Xoshiro256, lo: usize, hi: usize) {
        let w = hi - lo;
        // Try-lock a few random queues; a busy queue means another thread is
        // mutating it, so go elsewhere instead of waiting.
        for _ in 0..self.insert_tries {
            let i = lo + rng.index(w);
            if let Ok(mut heap) = self.queues[i].heap.try_lock() {
                Self::push_locked(&self.queues[i], &mut heap, entry);
                self.len.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        // Fall back to blocking on one random queue (no livelock).
        let i = lo + rng.index(w);
        let mut heap = self.queues[i].heap.lock().unwrap();
        Self::push_locked(&self.queues[i], &mut heap, entry);
        self.len.fetch_add(1, Ordering::Relaxed);
    }

    /// One two-choice pop attempt over `[lo, hi)`: compare the cached tops
    /// of two random queues, try-lock the better one.
    fn try_pop_two_choice(&self, rng: &mut Xoshiro256, lo: usize, hi: usize) -> Option<Entry> {
        let w = hi - lo;
        let i = lo + rng.index(w);
        let mut j = lo + rng.index(w);
        if w > 1 {
            while j == i {
                j = lo + rng.index(w);
            }
        }
        let ti = self.queues[i].top.load();
        let tj = self.queues[j].top.load();
        let best = if ti >= tj { i } else { j };
        if self.queues[best].top.load() == f64::NEG_INFINITY {
            return None;
        }
        if let Ok(mut heap) = self.queues[best].heap.try_lock() {
            if let Some(e) = Self::pop_locked(&self.queues[best], &mut heap) {
                self.len.fetch_sub(1, Ordering::Relaxed);
                return Some(e);
            }
        }
        None
    }

    /// Full sweep with blocking locks — guarantees progress when few
    /// entries remain, and makes `None` reliably mean "(momentarily)
    /// empty" across every queue, local or not.
    fn sweep_pop(&self) -> Option<Entry> {
        for i in 0..self.queues.len() {
            let mut heap = self.queues[i].heap.lock().unwrap();
            if let Some(e) = Self::pop_locked(&self.queues[i], &mut heap) {
                self.len.fetch_sub(1, Ordering::Relaxed);
                return Some(e);
            }
        }
        None
    }
}

impl Scheduler for Multiqueue {
    fn insert(&self, entry: Entry, rng: &mut Xoshiro256) {
        self.insert_in(entry, rng, 0, self.queues.len());
    }

    fn pop(&self, rng: &mut Xoshiro256) -> Option<Entry> {
        // A few two-choice attempts; on repeated failure do one full scan so
        // that "None" reliably means the queues were (momentarily) empty.
        for _ in 0..4 {
            if let Some(e) = self.try_pop_two_choice(rng, 0, self.queues.len()) {
                return Some(e);
            }
        }
        self.sweep_pop()
    }

    fn insert_hint(&self, entry: Entry, rng: &mut Xoshiro256, shard: Option<u32>) {
        match (&self.affinity, shard) {
            (Some(a), Some(s)) if !rng.bernoulli(a.spill) => {
                let (lo, hi) = a.range(s);
                self.insert_in(entry, rng, lo, hi);
            }
            _ => self.insert(entry, rng),
        }
    }

    fn pop_hint(&self, rng: &mut Xoshiro256, shard: Option<u32>) -> Option<Entry> {
        let (Some(a), Some(s)) = (&self.affinity, shard) else {
            return self.pop(rng);
        };
        let (lo, hi) = a.range(s);
        for _ in 0..4 {
            let (lo, hi) =
                if rng.bernoulli(a.spill) { (0, self.queues.len()) } else { (lo, hi) };
            if let Some(e) = self.try_pop_two_choice(rng, lo, hi) {
                return Some(e);
            }
        }
        // Local group (momentarily) empty: steal globally so liveness and
        // the "None ⟺ all queues empty" contract match the blind mode.
        self.sweep_pop()
    }

    fn approx_len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256 {
        Xoshiro256::seed_from_u64(7)
    }

    #[test]
    fn pop_returns_all_inserted() {
        let q = Multiqueue::new(8);
        let mut r = rng();
        for t in 0..1000u32 {
            q.insert(Entry { prio: r.next_f64(), task: t, epoch: 0 }, &mut r);
        }
        assert_eq!(q.approx_len(), 1000);
        let mut seen = std::collections::HashSet::new();
        while let Some(e) = q.pop(&mut r) {
            assert!(seen.insert(e.task));
        }
        assert_eq!(seen.len(), 1000);
        assert_eq!(q.approx_len(), 0);
        assert!(q.pop(&mut r).is_none());
    }

    #[test]
    fn rank_is_relaxed_but_bounded_in_practice() {
        // Insert n entries with distinct priorities; pop all; measure the
        // rank error of each pop (how many higher-priority entries were
        // still queued). With two-choice over m=8 queues the mean rank
        // error should be far below n.
        let n = 2000u32;
        let q = Multiqueue::new(8);
        let mut r = rng();
        for t in 0..n {
            q.insert(Entry { prio: t as f64, task: t, epoch: 0 }, &mut r);
        }
        let mut live: std::collections::BTreeSet<u32> = (0..n).collect();
        let mut total_rank = 0usize;
        let mut max_rank = 0usize;
        while let Some(e) = q.pop(&mut r) {
            // rank = number of live entries with higher priority
            let rank = live.range(e.task + 1..).count();
            total_rank += rank;
            max_rank = max_rank.max(rank);
            live.remove(&e.task);
        }
        assert!(live.is_empty());
        let mean = total_rank as f64 / n as f64;
        assert!(mean < 32.0, "mean rank error {mean} too high for m=8");
        assert!(max_rank < n as usize / 4, "max rank error {max_rank}");
    }

    #[test]
    fn single_queue_is_exact() {
        // m=1 degenerates to an exact queue (both choices hit the same heap).
        let q = Multiqueue::new(1);
        let mut r = rng();
        for (i, p) in [0.2, 0.8, 0.5].iter().enumerate() {
            q.insert(Entry { prio: *p, task: i as u32, epoch: 0 }, &mut r);
        }
        let order: Vec<f64> = std::iter::from_fn(|| q.pop(&mut r)).map(|e| e.prio).collect();
        assert_eq!(order, vec![0.8, 0.5, 0.2]);
    }

    #[test]
    fn concurrent_producers_consumers() {
        let q = std::sync::Arc::new(Multiqueue::for_threads(4, 4));
        let per = 2000u32;
        let popped = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let q = std::sync::Arc::clone(&q);
                s.spawn(move || {
                    let mut r = Xoshiro256::stream(3, t);
                    for i in 0..per {
                        q.insert(
                            Entry { prio: r.next_f64(), task: t as u32 * per + i, epoch: 0 },
                            &mut r,
                        );
                    }
                });
            }
            for t in 0..2u64 {
                let q = std::sync::Arc::clone(&q);
                let popped = std::sync::Arc::clone(&popped);
                s.spawn(move || {
                    let mut r = Xoshiro256::stream(11, t);
                    let mut local = Vec::new();
                    // Consume until we've seen nothing for a while.
                    let mut misses = 0;
                    while misses < 100 {
                        match q.pop(&mut r) {
                            Some(e) => {
                                local.push(e.task);
                                misses = 0;
                            }
                            None => {
                                misses += 1;
                                std::thread::yield_now();
                            }
                        }
                    }
                    popped.lock().unwrap().extend(local);
                });
            }
        });
        let mut all = popped.lock().unwrap().clone();
        let mut r = rng();
        while let Some(e) = q.pop(&mut r) {
            all.push(e.task);
        }
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 4 * per as usize, "no lost or duplicated entries");
    }

    #[test]
    fn for_threads_minimum_two() {
        let q = Multiqueue::for_threads(1, 1);
        assert_eq!(q.num_queues(), 2);
    }

    #[test]
    fn shard_affine_geometry() {
        // Each shard group gets at least two heaps even when p·c is small.
        let q = Multiqueue::shard_affine(1, 1, 7, 0.1);
        assert_eq!(q.num_shard_groups(), 7);
        assert!(q.num_queues() >= 14);
        let q = Multiqueue::shard_affine(4, 4, 2, 0.1);
        assert_eq!(q.num_queues(), 16);
        assert_eq!(q.num_shard_groups(), 2);
    }

    #[test]
    fn shard_affine_preserves_multiset() {
        // No entry is lost or duplicated under hinted inserts and pops,
        // regardless of shard routing or spill.
        for spill in [0.0, 0.25, 1.0] {
            let q = Multiqueue::shard_affine(2, 4, 4, spill);
            let mut r = rng();
            for t in 0..1000u32 {
                q.insert_hint(Entry { prio: r.next_f64(), task: t, epoch: 0 }, &mut r, Some(t % 4));
            }
            assert_eq!(q.approx_len(), 1000);
            let mut seen = std::collections::HashSet::new();
            let mut home = 0u32;
            while let Some(e) = q.pop_hint(&mut r, Some(home)) {
                assert!(seen.insert(e.task));
                home = (home + 1) % 4;
            }
            assert_eq!(seen.len(), 1000, "spill={spill}");
            assert_eq!(q.approx_len(), 0);
        }
    }

    #[test]
    fn zero_spill_keeps_entries_shard_local() {
        // With spill = 0, an entry inserted for shard s is always popped by
        // a worker hinting s before workers of other shards can see it via
        // two-choice (they can only reach it through the fallback sweep,
        // which this test never triggers because shard 0 stays nonempty).
        let q = Multiqueue::shard_affine(2, 4, 2, 0.0);
        let mut r = rng();
        for t in 0..100u32 {
            q.insert_hint(Entry { prio: t as f64, task: t, epoch: 0 }, &mut r, Some(0));
        }
        // Popping with the shard-0 hint drains everything without the
        // global sweep; the shard-1 group never held an entry.
        let mut popped = 0;
        while let Some(_e) = q.pop_hint(&mut r, Some(0)) {
            popped += 1;
        }
        assert_eq!(popped, 100);
    }

    #[test]
    fn hint_on_blind_queue_is_ignored() {
        let q = Multiqueue::new(4);
        let mut r = rng();
        q.insert_hint(Entry { prio: 1.0, task: 0, epoch: 0 }, &mut r, Some(3));
        assert_eq!(q.pop_hint(&mut r, Some(1)).unwrap().task, 0);
    }

    #[test]
    fn cross_shard_steal_via_sweep() {
        // A worker whose home shard is empty must still drain other
        // shards' entries (the liveness half of the affinity contract).
        let q = Multiqueue::shard_affine(2, 4, 2, 0.0);
        let mut r = rng();
        q.insert_hint(Entry { prio: 1.0, task: 7, epoch: 0 }, &mut r, Some(1));
        let e = q.pop_hint(&mut r, Some(0)).expect("steals from shard 1");
        assert_eq!(e.task, 7);
        assert!(q.pop_hint(&mut r, Some(0)).is_none());
    }

    #[test]
    fn shard_affine_concurrent_producers_consumers() {
        let q = std::sync::Arc::new(Multiqueue::shard_affine(4, 4, 4, 0.1));
        let per = 1000u32;
        let popped = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let q = std::sync::Arc::clone(&q);
                s.spawn(move || {
                    let mut r = Xoshiro256::stream(21, t);
                    for i in 0..per {
                        let task = t as u32 * per + i;
                        q.insert_hint(
                            Entry { prio: r.next_f64(), task, epoch: 0 },
                            &mut r,
                            Some(task % 4),
                        );
                    }
                });
            }
            for t in 0..2u64 {
                let q = std::sync::Arc::clone(&q);
                let popped = std::sync::Arc::clone(&popped);
                s.spawn(move || {
                    let mut r = Xoshiro256::stream(31, t);
                    let mut local = Vec::new();
                    let mut misses = 0;
                    while misses < 100 {
                        match q.pop_hint(&mut r, Some(t as u32)) {
                            Some(e) => {
                                local.push(e.task);
                                misses = 0;
                            }
                            None => {
                                misses += 1;
                                std::thread::yield_now();
                            }
                        }
                    }
                    popped.lock().unwrap().extend(local);
                });
            }
        });
        let mut all = popped.lock().unwrap().clone();
        let mut r = rng();
        while let Some(e) = q.pop_hint(&mut r, Some(0)) {
            all.push(e.task);
        }
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 4 * per as usize, "no lost or duplicated entries");
    }
}
