//! The exact (strict) scheduler: a single binary heap behind one lock.
//!
//! This is the paper's *Coarse-Grained* baseline — linearizable
//! `DeleteMin`, always returning the true maximum-priority entry, at the
//! cost of all threads contending on one lock. Its poor scaling is the
//! motivation for the relaxed Multiqueue.

use super::{Entry, Scheduler};
use crate::util::Xoshiro256;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// The exact scheduler: one mutex-protected binary max-heap.
pub struct ExactQueue {
    heap: Mutex<BinaryHeap<Entry>>,
    len: AtomicUsize,
}

impl ExactQueue {
    /// Empty queue.
    pub fn new() -> Self {
        ExactQueue { heap: Mutex::new(BinaryHeap::new()), len: AtomicUsize::new(0) }
    }

    /// Empty queue with pre-allocated capacity.
    pub fn with_capacity(cap: usize) -> Self {
        ExactQueue {
            heap: Mutex::new(BinaryHeap::with_capacity(cap)),
            len: AtomicUsize::new(0),
        }
    }
}

impl Default for ExactQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for ExactQueue {
    fn insert(&self, entry: Entry, _rng: &mut Xoshiro256) {
        let mut h = self.heap.lock().unwrap();
        h.push(entry);
        self.len.fetch_add(1, Ordering::Relaxed);
    }

    fn pop(&self, _rng: &mut Xoshiro256) -> Option<Entry> {
        let mut h = self.heap.lock().unwrap();
        let e = h.pop();
        if e.is_some() {
            self.len.fetch_sub(1, Ordering::Relaxed);
        }
        e
    }

    fn approx_len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256 {
        Xoshiro256::seed_from_u64(1)
    }

    #[test]
    fn strict_priority_order() {
        let q = ExactQueue::new();
        let mut r = rng();
        for (i, p) in [0.3, 0.9, 0.1, 0.5].iter().enumerate() {
            q.insert(Entry { prio: *p, task: i as u32, epoch: 0 }, &mut r);
        }
        let order: Vec<f64> = std::iter::from_fn(|| q.pop(&mut r)).map(|e| e.prio).collect();
        assert_eq!(order, vec![0.9, 0.5, 0.3, 0.1]);
        assert_eq!(q.approx_len(), 0);
    }

    #[test]
    fn empty_pop_none() {
        let q = ExactQueue::new();
        assert!(q.pop(&mut rng()).is_none());
    }

    #[test]
    fn concurrent_no_lost_entries() {
        let q = std::sync::Arc::new(ExactQueue::new());
        let n_threads = 4;
        let per_thread = 500;
        std::thread::scope(|s| {
            for t in 0..n_threads {
                let q = std::sync::Arc::clone(&q);
                s.spawn(move || {
                    let mut r = Xoshiro256::stream(9, t as u64);
                    for i in 0..per_thread {
                        q.insert(
                            Entry { prio: r.next_f64(), task: (t * per_thread + i) as u32, epoch: 0 },
                            &mut r,
                        );
                    }
                });
            }
        });
        assert_eq!(q.approx_len(), n_threads * per_thread);
        let mut r = rng();
        let mut seen = std::collections::HashSet::new();
        while let Some(e) = q.pop(&mut r) {
            assert!(seen.insert(e.task), "duplicate task {}", e.task);
        }
        assert_eq!(seen.len(), n_threads * per_thread);
    }
}
