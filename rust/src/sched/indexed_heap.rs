//! Indexed (position-tracking) binary max-heap for the *sequential*
//! residual engine.
//!
//! The concurrent engines must use lazy epoch-validated entries (heaps
//! can't do increase-key under concurrent access), but the sequential
//! baseline pays dearly for the churn: every refresh inserts a fresh entry
//! and every pop sifts past stale ones (≈27% of baseline cycles in the
//! §Perf profile). This heap keeps exactly one slot per task and supports
//! `update(task, prio)` via sift-up/down in place, eliminating stale
//! traffic entirely.

/// Max-heap over task ids `0..n` with in-place priority updates.
pub struct IndexedHeap {
    /// Heap array of task ids.
    heap: Vec<u32>,
    /// Position of each task in `heap`, or `ABSENT`.
    pos: Vec<u32>,
    /// Current priority of each task (valid when present).
    prio: Vec<f64>,
}

const ABSENT: u32 = u32::MAX;
/// Heap arity: 4-ary halves the sift-down depth vs binary and keeps the
/// children of a node on one cache line — measurably faster for this
/// update-heavy workload (EXPERIMENTS.md §Perf).
const ARITY: usize = 4;

impl IndexedHeap {
    /// Heap over tasks `0..n`, initially empty.
    pub fn new(n: usize) -> Self {
        IndexedHeap { heap: Vec::with_capacity(n), pos: vec![ABSENT; n], prio: vec![0.0; n] }
    }

    /// Number of queued tasks.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no task is queued.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// True when `task` is currently queued.
    pub fn contains(&self, task: u32) -> bool {
        self.pos[task as usize] != ABSENT
    }

    /// Current priority of `task`, if queued.
    pub fn priority(&self, task: u32) -> Option<f64> {
        self.contains(task).then(|| self.prio[task as usize])
    }

    /// Insert `task` or update its priority in place.
    pub fn update(&mut self, task: u32, prio: f64) {
        let t = task as usize;
        if self.pos[t] == ABSENT {
            self.prio[t] = prio;
            self.pos[t] = self.heap.len() as u32;
            self.heap.push(task);
            self.sift_up(self.heap.len() - 1);
        } else {
            let old = self.prio[t];
            self.prio[t] = prio;
            let p = self.pos[t] as usize;
            if prio > old {
                self.sift_up(p);
            } else if prio < old {
                self.sift_down(p);
            }
        }
    }

    /// Remove `task` if present.
    pub fn remove(&mut self, task: u32) {
        let t = task as usize;
        let p = self.pos[t];
        if p == ABSENT {
            return;
        }
        let p = p as usize;
        let last = self.heap.len() - 1;
        self.swap(p, last);
        self.heap.pop();
        self.pos[t] = ABSENT;
        if p < self.heap.len() {
            let moved_prio = self.prio[self.heap[p] as usize];
            // Restore invariant in whichever direction is needed.
            if p > 0 && moved_prio > self.prio[self.heap[(p - 1) / ARITY] as usize] {
                self.sift_up(p);
            } else {
                self.sift_down(p);
            }
        }
    }

    /// Pop the max-priority task.
    pub fn pop(&mut self) -> Option<(u32, f64)> {
        if self.heap.is_empty() {
            return None;
        }
        let top = self.heap[0];
        let prio = self.prio[top as usize];
        self.remove(top);
        Some((top, prio))
    }

    /// Highest-priority entry without removing it.
    pub fn peek(&self) -> Option<(u32, f64)> {
        self.heap.first().map(|&t| (t, self.prio[t as usize]))
    }

    #[inline]
    fn swap(&mut self, a: usize, b: usize) {
        self.heap.swap(a, b);
        self.pos[self.heap[a] as usize] = a as u32;
        self.pos[self.heap[b] as usize] = b as u32;
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / ARITY;
            if self.prio[self.heap[i] as usize] <= self.prio[self.heap[parent] as usize] {
                break;
            }
            self.swap(i, parent);
            i = parent;
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let first = ARITY * i + 1;
            if first >= n {
                break;
            }
            let last = (first + ARITY).min(n);
            let mut best = first;
            let mut best_prio = self.prio[self.heap[first] as usize];
            for k in first + 1..last {
                let p = self.prio[self.heap[k] as usize];
                if p > best_prio {
                    best = k;
                    best_prio = p;
                }
            }
            if best_prio <= self.prio[self.heap[i] as usize] {
                break;
            }
            self.swap(i, best);
            i = best;
        }
    }

    /// Debug invariant check (tests only).
    #[cfg(test)]
    fn validate(&self) {
        for i in 1..self.heap.len() {
            let parent = (i - 1) / ARITY;
            assert!(
                self.prio[self.heap[parent] as usize] >= self.prio[self.heap[i] as usize],
                "heap property violated at {i}"
            );
        }
        for (t, &p) in self.pos.iter().enumerate() {
            if p != ABSENT {
                assert_eq!(self.heap[p as usize] as usize, t, "pos table broken");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    #[test]
    fn push_pop_order() {
        let mut h = IndexedHeap::new(5);
        for (t, p) in [(0u32, 0.3), (1, 0.9), (2, 0.1), (3, 0.5), (4, 0.7)] {
            h.update(t, p);
        }
        let order: Vec<u32> = std::iter::from_fn(|| h.pop().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![1, 4, 3, 0, 2]);
    }

    #[test]
    fn update_moves_both_directions() {
        let mut h = IndexedHeap::new(3);
        h.update(0, 0.1);
        h.update(1, 0.2);
        h.update(2, 0.3);
        h.update(0, 0.9); // increase
        assert_eq!(h.peek(), Some((0, 0.9)));
        h.update(0, 0.05); // decrease
        assert_eq!(h.peek(), Some((2, 0.3)));
        h.validate();
    }

    #[test]
    fn remove_middle() {
        let mut h = IndexedHeap::new(6);
        for t in 0..6u32 {
            h.update(t, t as f64);
        }
        h.remove(3);
        assert!(!h.contains(3));
        h.validate();
        let order: Vec<u32> = std::iter::from_fn(|| h.pop().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![5, 4, 2, 1, 0]);
    }

    #[test]
    fn remove_absent_is_noop() {
        let mut h = IndexedHeap::new(2);
        h.update(0, 1.0);
        h.remove(1);
        assert_eq!(h.len(), 1);
    }

    #[test]
    fn randomized_against_reference() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for _case in 0..50 {
            let n = 2 + rng.index(64);
            let mut h = IndexedHeap::new(n);
            let mut reference: std::collections::HashMap<u32, f64> = Default::default();
            for _ in 0..200 {
                let t = rng.index(n) as u32;
                match rng.index(3) {
                    0 | 1 => {
                        let p = rng.next_f64();
                        h.update(t, p);
                        reference.insert(t, p);
                    }
                    _ => {
                        h.remove(t);
                        reference.remove(&t);
                    }
                }
                h.validate();
                assert_eq!(h.len(), reference.len());
            }
            // Drain: must come out in sorted order and match the map.
            let mut last = f64::INFINITY;
            let mut seen = 0;
            while let Some((t, p)) = h.pop() {
                assert!(p <= last);
                last = p;
                assert_eq!(reference.get(&t), Some(&p));
                seen += 1;
            }
            assert_eq!(seen, reference.len());
        }
    }
}
