//! Task schedulers: the exact priority queue, the paper's relaxed
//! Multiqueue, and the naive random-queue scheduler used by Random Splash.
//!
//! ## Entry / epoch protocol
//!
//! Priorities of BP tasks change as neighboring messages are updated, but
//! concurrent heaps cannot efficiently support `increase_key`. All
//! schedulers here use the standard *lazy entry* idiom instead:
//!
//! - every priority change bumps the task's **epoch** in [`TaskStates`] and
//!   inserts a fresh [`Entry`] carrying that epoch;
//! - a popped entry whose epoch no longer matches the task's current epoch
//!   is *stale* and discarded;
//! - before processing, a worker must **claim** the task (CAS on the claim
//!   bit) so a task is never processed by two threads at once — the paper's
//!   "marked as in-process".
//!
//! Every inserted entry is popped exactly once, so a global counter of
//! in-queue entries (maintained by the coordinator) gives quiescence
//! detection for termination.
//!
//! ## Locality (shard affinity)
//!
//! The hint variants [`Scheduler::insert_hint`] / [`Scheduler::pop_hint`]
//! carry the locality layer's shard assignment (see
//! [`crate::model::partition`]). The shard-affine [`Multiqueue`] uses them
//! to keep a task's entries on queues owned by its shard; all other
//! schedulers ignore them. Hints never affect the entry/epoch/claim
//! protocol or the quiescence accounting — they only bias *which* queue an
//! operation touches.

pub mod exact;
pub mod indexed_heap;
pub mod multiqueue;
pub mod random_queues;

pub use exact::ExactQueue;
pub use indexed_heap::IndexedHeap;
pub use multiqueue::Multiqueue;
pub use random_queues::RandomQueues;

use crate::util::Xoshiro256;
use std::sync::atomic::{AtomicU64, Ordering};

/// A queue entry: task id, its priority at insertion time, and the epoch
/// that validates it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Entry {
    /// Priority at insertion time.
    pub prio: f64,
    /// Task id.
    pub task: u32,
    /// Epoch validating this entry against [`TaskStates`].
    pub epoch: u32,
}

impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Max-heap on priority; priorities are never NaN (residuals are
        // finite by construction). Tie-break on task id for determinism.
        self.prio
            .partial_cmp(&other.prio)
            .expect("priority must not be NaN")
            .then(self.task.cmp(&other.task))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The scheduler abstraction shared by all engines.
///
/// `insert` and `pop` take the worker's thread-local RNG; the exact queue
/// ignores it, the relaxed queues use it for queue choice.
///
/// The `*_hint` variants additionally carry a **shard hint** from the
/// locality layer (the task's shard on insert, the worker's home shard on
/// pop). Schedulers without a locality notion ignore the hint — the
/// default implementations delegate to the blind operations — while the
/// shard-affine [`Multiqueue`] routes the operation to the hinted shard's
/// queue group (subject to its spill probability). The hint is advisory:
/// correctness (no lost entries, `pop → None` ⟺ momentarily empty) never
/// depends on it.
pub trait Scheduler: Send + Sync {
    /// Insert an entry (relaxed schedulers pick a random queue).
    fn insert(&self, entry: Entry, rng: &mut Xoshiro256);
    /// Pop some entry (for relaxed schedulers: from the better of two random
    /// queues). `None` means "no entry found right now" — the queues looked
    /// empty; the coordinator decides whether that means termination.
    fn pop(&self, rng: &mut Xoshiro256) -> Option<Entry>;
    /// Estimated number of entries across all internal queues.
    fn approx_len(&self) -> usize;

    /// [`Scheduler::insert`] with the task's shard as a locality hint.
    fn insert_hint(&self, entry: Entry, rng: &mut Xoshiro256, shard: Option<u32>) {
        let _ = shard;
        self.insert(entry, rng);
    }

    /// [`Scheduler::pop`] with the worker's home shard as a locality hint.
    fn pop_hint(&self, rng: &mut Xoshiro256, shard: Option<u32>) -> Option<Entry> {
        let _ = shard;
        self.pop(rng)
    }

    /// Insert a batch of entries that became schedulable together (e.g.
    /// one node's refreshed out-edges). Semantically identical to calling
    /// [`Scheduler::insert_hint`] once per entry — which is exactly what
    /// the default does — but relaxed schedulers may amortize queue choice
    /// and locking over the whole batch (the [`Multiqueue`] pays one RNG
    /// draw + one lock acquisition per batch instead of per entry).
    fn insert_batch(&self, entries: &[Entry], rng: &mut Xoshiro256, shard: Option<u32>) {
        for &e in entries {
            self.insert_hint(e, rng, shard);
        }
    }

    /// Pop up to `max` entries into `out`; returns how many were popped.
    /// Returning 0 carries the same meaning as [`Scheduler::pop`] →
    /// `None`: every queue looked (momentarily) empty — the signal the
    /// quiescence accounting relies on. The default delegates to
    /// [`Scheduler::pop_hint`] per entry; the [`Multiqueue`] overrides it
    /// to drain several entries per locked sub-queue visit.
    fn pop_batch(
        &self,
        rng: &mut Xoshiro256,
        shard: Option<u32>,
        max: usize,
        out: &mut Vec<Entry>,
    ) -> usize {
        let mut n = 0;
        while n < max {
            match self.pop_hint(rng, shard) {
                Some(e) => {
                    out.push(e);
                    n += 1;
                }
                None => break,
            }
        }
        n
    }

    /// Sweep **every** internal queue and return true only if all of them
    /// were observed empty. Unlike a failed [`Scheduler::pop`] — which for
    /// relaxed schedulers only proves the *sampled* queues looked empty —
    /// this is a linearizable check against entries that were fully
    /// inserted before the call: a termination token must not be forwarded
    /// on the strength of an unlucky two-choice sample. Entries being
    /// inserted concurrently may still be missed; the quiescence counters
    /// (and, distributed, the token color) cover that window.
    fn is_definitely_empty(&self) -> bool {
        self.approx_len() == 0
    }
}

/// Shard-affinity configuration handed to [`SchedChoice::build`] when the
/// run's partition axis is on: how many shards the task universe has, and
/// the probability that an operation ignores affinity (see
/// [`Multiqueue::shard_affine`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardAffinity {
    /// Number of task shards (queue groups).
    pub shards: usize,
    /// Spill probability in [0, 1].
    pub spill: f64,
}

/// Which scheduler an [`exec::WorkerPool`](crate::exec::WorkerPool) run
/// uses — the paper's three contenders as a value, so engines pass a
/// choice instead of plumbing their own queue construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedChoice {
    /// One lock-protected exact priority queue (the "Coarse-Grained"
    /// baselines).
    Exact,
    /// The relaxed Multiqueue (`queues_per_thread` heaps per worker,
    /// two-choice pops) — the paper's headline scheduler.
    Relaxed,
    /// The journal version's naive random queues: random insert, random
    /// single-queue delete, no rank bound (Random Splash).
    Random,
}

impl SchedChoice {
    /// Build the scheduler for a pool of `threads` workers over
    /// `num_tasks` tasks. `affinity` is the run's partition axis: when set,
    /// the relaxed Multiqueue is built shard-affine (the exact and random
    /// schedulers have no locality notion and ignore it).
    pub fn build(
        self,
        num_tasks: usize,
        threads: usize,
        queues_per_thread: usize,
        affinity: Option<ShardAffinity>,
    ) -> Box<dyn Scheduler> {
        match self {
            SchedChoice::Exact => Box::new(ExactQueue::with_capacity(num_tasks)),
            SchedChoice::Relaxed => match affinity {
                Some(a) => Box::new(Multiqueue::shard_affine(
                    threads,
                    queues_per_thread,
                    a.shards,
                    a.spill,
                )),
                None => Box::new(Multiqueue::for_threads(threads, queues_per_thread)),
            },
            SchedChoice::Random => Box::new(RandomQueues::new(threads.max(2))),
        }
    }
}

/// Per-task claim bit + epoch word.
///
/// Layout: bit 63 = claimed; low 32 bits = epoch (wrapping; bits 32–62 may
/// accumulate carries and are masked off on read).
pub struct TaskStates {
    words: Vec<AtomicU64>,
}

const CLAIM_BIT: u64 = 1 << 63;
const EPOCH_MASK: u64 = 0xFFFF_FFFF;

impl TaskStates {
    /// States for tasks `0..n`, all unclaimed at epoch 0.
    pub fn new(n: usize) -> Self {
        let mut words = Vec::with_capacity(n);
        words.resize_with(n, || AtomicU64::new(0));
        TaskStates { words }
    }

    /// Number of tasks tracked.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    /// True when no task is tracked.
    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Current epoch of `task`.
    #[inline]
    pub fn epoch(&self, task: u32) -> u32 {
        (self.words[task as usize].load(Ordering::Acquire) & EPOCH_MASK) as u32
    }

    #[inline]
    /// True while some worker holds `task`'s claim bit.
    pub fn is_claimed(&self, task: u32) -> bool {
        self.words[task as usize].load(Ordering::Acquire) & CLAIM_BIT != 0
    }

    /// Invalidate all existing entries for `task` and return the fresh
    /// epoch to attach to a new entry.
    #[inline]
    pub fn bump(&self, task: u32) -> u32 {
        let old = self.words[task as usize].fetch_add(1, Ordering::AcqRel);
        (old.wrapping_add(1) & EPOCH_MASK) as u32
    }

    /// Claim `task` if it is unclaimed *and* its epoch still equals
    /// `epoch`. Returns false on stale entry or concurrent claim.
    pub fn try_claim(&self, task: u32, epoch: u32) -> bool {
        let w = &self.words[task as usize];
        let mut cur = w.load(Ordering::Acquire);
        loop {
            if cur & CLAIM_BIT != 0 || (cur & EPOCH_MASK) as u32 != epoch {
                return false;
            }
            match w.compare_exchange_weak(
                cur,
                cur | CLAIM_BIT,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return true,
                Err(actual) => cur = actual,
            }
        }
    }

    /// Release a claim (the claim holder only).
    #[inline]
    pub fn release(&self, task: u32) {
        self.words[task as usize].fetch_and(!CLAIM_BIT, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn entry_ordering() {
        let a = Entry { prio: 1.0, task: 0, epoch: 0 };
        let b = Entry { prio: 2.0, task: 1, epoch: 0 };
        assert!(b > a);
        let mut heap = std::collections::BinaryHeap::new();
        heap.push(a);
        heap.push(b);
        assert_eq!(heap.pop().unwrap().prio, 2.0);
    }

    #[test]
    fn entry_tie_break_deterministic() {
        let a = Entry { prio: 1.0, task: 3, epoch: 0 };
        let b = Entry { prio: 1.0, task: 7, epoch: 0 };
        assert!(b > a);
    }

    #[test]
    fn claim_lifecycle() {
        let ts = TaskStates::new(4);
        assert_eq!(ts.epoch(2), 0);
        assert!(!ts.is_claimed(2));
        assert!(ts.try_claim(2, 0));
        assert!(ts.is_claimed(2));
        // second claim fails
        assert!(!ts.try_claim(2, 0));
        ts.release(2);
        assert!(!ts.is_claimed(2));
        assert!(ts.try_claim(2, 0));
    }

    #[test]
    fn stale_epoch_rejected() {
        let ts = TaskStates::new(2);
        let e1 = ts.bump(0);
        assert_eq!(e1, 1);
        assert!(!ts.try_claim(0, 0), "old epoch is stale");
        assert!(ts.try_claim(0, e1));
    }

    #[test]
    fn bump_while_claimed_preserves_claim() {
        let ts = TaskStates::new(1);
        assert!(ts.try_claim(0, 0));
        let e = ts.bump(0);
        assert!(ts.is_claimed(0));
        assert_eq!(ts.epoch(0), e);
        // entry with new epoch still can't claim while held
        assert!(!ts.try_claim(0, e));
        ts.release(0);
        assert!(ts.try_claim(0, e));
    }

    #[test]
    fn concurrent_claim_exclusive() {
        let ts = Arc::new(TaskStates::new(1));
        let wins: usize = std::thread::scope(|s| {
            (0..8)
                .map(|_| {
                    let ts = Arc::clone(&ts);
                    s.spawn(move || ts.try_claim(0, 0) as usize)
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .sum()
        });
        assert_eq!(wins, 1, "exactly one thread may claim");
    }
}
