//! The naive relaxed queue used by the journal version of Residual Splash
//! (Gonzalez et al.): `p` exact priority queues with *random* insert and
//! *random single-queue* delete.
//!
//! Crucially, `pop` examines ONE random queue (no two-choice), so — as
//! shown by Alistarh et al. [PODC'17] — this structure is **not** a
//! q-relaxed scheduler for any fixed q: its rank error diverges as
//! operations accumulate, effectively degrading toward random task
//! selection. The paper includes it ("RS") precisely to demonstrate that a
//! principled relaxed scheduler matters; we reproduce it faithfully.

use super::{Entry, Scheduler};
use crate::util::{AtomicF64, CachePadded, Xoshiro256};
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

struct SubQueue {
    heap: Mutex<BinaryHeap<Entry>>,
    top: AtomicF64,
}

/// The journal version's naive random queues (no rank bound).
pub struct RandomQueues {
    queues: Vec<CachePadded<SubQueue>>,
    len: AtomicUsize,
}

impl RandomQueues {
    /// `m` internal queues (at least 2, for distinct two-choice indices).
    pub fn new(m: usize) -> Self {
        assert!(m >= 1);
        let mut queues = Vec::with_capacity(m);
        queues.resize_with(m, || {
            CachePadded(SubQueue {
                heap: Mutex::new(BinaryHeap::new()),
                top: AtomicF64::new(f64::NEG_INFINITY),
            })
        });
        RandomQueues { queues, len: AtomicUsize::new(0) }
    }

    /// Number of internal queues.
    pub fn num_queues(&self) -> usize {
        self.queues.len()
    }
}

impl Scheduler for RandomQueues {
    fn insert(&self, entry: Entry, rng: &mut Xoshiro256) {
        let i = rng.index(self.queues.len());
        let q = &self.queues[i];
        let mut heap = q.heap.lock().unwrap();
        heap.push(entry);
        q.top.store(heap.peek().map_or(f64::NEG_INFINITY, |e| e.prio));
        self.len.fetch_add(1, Ordering::Relaxed);
    }

    fn pop(&self, rng: &mut Xoshiro256) -> Option<Entry> {
        let m = self.queues.len();
        // One random queue; a few retries on empty picks, then a full scan
        // so None reliably signals emptiness.
        for _ in 0..4 {
            let i = rng.index(m);
            let q = &self.queues[i];
            if q.top.load() == f64::NEG_INFINITY {
                continue;
            }
            let mut heap = q.heap.lock().unwrap();
            if let Some(e) = heap.pop() {
                q.top.store(heap.peek().map_or(f64::NEG_INFINITY, |e| e.prio));
                self.len.fetch_sub(1, Ordering::Relaxed);
                return Some(e);
            }
        }
        for i in 0..m {
            let q = &self.queues[i];
            let mut heap = q.heap.lock().unwrap();
            if let Some(e) = heap.pop() {
                q.top.store(heap.peek().map_or(f64::NEG_INFINITY, |e| e.prio));
                self.len.fetch_sub(1, Ordering::Relaxed);
                return Some(e);
            }
        }
        None
    }

    fn approx_len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Xoshiro256 {
        Xoshiro256::seed_from_u64(5)
    }

    #[test]
    fn no_lost_entries() {
        let q = RandomQueues::new(4);
        let mut r = rng();
        for t in 0..500u32 {
            q.insert(Entry { prio: t as f64, task: t, epoch: 0 }, &mut r);
        }
        let mut seen = std::collections::HashSet::new();
        while let Some(e) = q.pop(&mut r) {
            assert!(seen.insert(e.task));
        }
        assert_eq!(seen.len(), 500);
    }

    #[test]
    fn rank_error_worse_than_multiqueue() {
        // Statistical demonstration of the structural difference: random
        // single-queue delete has higher mean rank error than two-choice
        // (same number of sub-queues, same entries).
        let n = 2000u32;
        let mq = super::super::Multiqueue::new(8);
        let rq = RandomQueues::new(8);
        let mut r = rng();
        for t in 0..n {
            mq.insert(Entry { prio: t as f64, task: t, epoch: 0 }, &mut r);
            rq.insert(Entry { prio: t as f64, task: t, epoch: 0 }, &mut r);
        }
        let mean_rank = |pop: &mut dyn FnMut() -> Option<Entry>| {
            let mut live: std::collections::BTreeSet<u32> = (0..n).collect();
            let mut total = 0usize;
            while let Some(e) = pop() {
                total += live.range(e.task + 1..).count();
                live.remove(&e.task);
            }
            total as f64 / n as f64
        };
        let mut r1 = rng();
        let mq_rank = mean_rank(&mut || mq.pop(&mut r1));
        let mut r2 = rng();
        let rq_rank = mean_rank(&mut || rq.pop(&mut r2));
        assert!(
            rq_rank > mq_rank * 2.0,
            "random-queue rank {rq_rank} should exceed multiqueue rank {mq_rank}"
        );
    }

    #[test]
    fn empty_returns_none() {
        let q = RandomQueues::new(3);
        assert!(q.pop(&mut rng()).is_none());
    }
}
