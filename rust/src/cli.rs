//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports the subcommand + flags shape used by the `relaxed-bp` binary:
//! `relaxed-bp <subcommand> [--flag value] [--switch] [positional...]`.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand, `--key value` options, `--switch`
/// booleans, and positional arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First bare token (`run`, `bench`, …).
    pub subcommand: Option<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Value-less `--switch` flags (must be pre-declared).
    pub switches: Vec<String>,
    /// Bare tokens after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    /// `known_switches` lists flags that take no value; everything else that
    /// starts with `--` consumes the next token as its value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, known_switches: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                    continue;
                }
                if known_switches.contains(&name) {
                    out.switches.push(name.to_string());
                    continue;
                }
                let val = it
                    .next()
                    .ok_or_else(|| anyhow!("option --{name} expects a value"))?;
                out.options.insert(name.to_string(), val);
            } else if tok.starts_with('-') && tok.len() > 1 {
                bail!("short options are not supported: {tok}");
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments (argv[0] excluded).
    pub fn from_env(known_switches: &[&str]) -> Result<Args> {
        Args::parse(std::env::args().skip(1), known_switches)
    }

    /// Raw value of `--key`, if given.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Parsed value of `--key`; `None` when absent, `Err` on a bad value.
    pub fn opt_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("bad value for --{key}: {e}")),
        }
    }

    /// Parsed value of `--key`, or `default` when absent.
    pub fn opt_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.opt_parse(key)?.unwrap_or(default))
    }

    /// Parse a comma-separated option value (`--threads 1,2,4`); `None`
    /// when the option is absent.
    pub fn opt_csv<T: std::str::FromStr>(&self, key: &str) -> Result<Option<Vec<T>>>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(key) {
            None => Ok(None),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse::<T>()
                        .map_err(|e| anyhow!("bad value '{p}' for --{key}: {e}"))
                })
                .collect::<Result<Vec<T>>>()
                .map(Some),
        }
    }

    /// Value of `--key` as an owned path, if given (for directory/file
    /// options like `--save-model`).
    pub fn opt_path(&self, key: &str) -> Option<std::path::PathBuf> {
        self.opt(key).map(std::path::PathBuf::from)
    }

    /// True when `--name` was given (must be listed in `known_switches`).
    pub fn has_switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn basic_subcommand_and_options() {
        let a = Args::parse(
            sv(&["run", "--model", "ising:300", "--threads", "8", "extra"]),
            &[],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.opt("model"), Some("ising:300"));
        assert_eq!(a.opt_or::<usize>("threads", 1).unwrap(), 8);
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn key_equals_value() {
        let a = Args::parse(sv(&["run", "--seed=7"]), &[]).unwrap();
        assert_eq!(a.opt_or::<u64>("seed", 0).unwrap(), 7);
    }

    #[test]
    fn switches() {
        let a = Args::parse(sv(&["bench", "--verbose", "--out", "x"]), &["verbose"]).unwrap();
        assert!(a.has_switch("verbose"));
        assert_eq!(a.opt("out"), Some("x"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(sv(&["run", "--model"]), &[]).is_err());
    }

    #[test]
    fn bad_parse_errors() {
        let a = Args::parse(sv(&["run", "--threads", "NaNcy"]), &[]).unwrap();
        assert!(a.opt_or::<usize>("threads", 1).is_err());
    }

    #[test]
    fn short_flags_rejected() {
        assert!(Args::parse(sv(&["-x"]), &[]).is_err());
    }

    #[test]
    fn default_when_missing() {
        let a = Args::parse(sv(&["run"]), &[]).unwrap();
        assert_eq!(a.opt_or::<f64>("epsilon", 1e-5).unwrap(), 1e-5);
        assert_eq!(a.opt_parse::<usize>("threads").unwrap(), None);
    }

    #[test]
    fn csv_lists() {
        let a = Args::parse(sv(&["bench", "--threads", "1, 2,4"]), &[]).unwrap();
        assert_eq!(a.opt_csv::<usize>("threads").unwrap(), Some(vec![1, 2, 4]));
        assert_eq!(a.opt_csv::<usize>("families").unwrap(), None);
        let bad = Args::parse(sv(&["bench", "--threads", "1,x"]), &[]).unwrap();
        assert!(bad.opt_csv::<usize>("threads").is_err());
    }
}
