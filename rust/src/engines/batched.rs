//! Batched relaxed residual BP — the three-layer extension.
//!
//! Identical scheduling semantics to relaxed residual BP, but each worker
//! drains up to `batch` tasks from the Multiqueue before computing (the
//! pool's batch-draining mode), then performs all lookahead refreshes for
//! the combined affected-edge set as **one dense batch**. The batch
//! compute is pluggable via [`BatchCompute`]:
//!
//! - [`NativeBatch`] — scalar loop (baseline / arbitrary domains);
//! - `runtime::batch::PjrtBatch` — the AOT-compiled JAX/Pallas kernel
//!   executed through the PJRT CPU client (binary models), putting layers
//!   L1/L2 on the request path with Python long gone.
//!
//! Batching amortizes scheduler traffic (one pop ≈ splash's motivation)
//! and exposes SIMD/MXU-shaped work to the kernel layer.
//!
//! With the update-kernel axis on (`RunConfig::fused`, the default) and
//! the native backend, the affected-set refresh instead runs through the
//! node-centric fused kernel (`Lookahead::refresh_node` per touched dst
//! node — O(deg) instead of O(deg²) gathers) and requeues through one
//! batched scheduler insert; an explicitly requested PJRT backend keeps
//! the dense edge-list path, which is that configuration's point.

use super::{Engine, EngineStats};
use crate::bp::{
    compute_message_with, Kernel, Lookahead, Messages, MsgScratch, MsgSource, NodeScratch,
};
use crate::configio::RunConfig;
use crate::exec::{ExecCtx, TaskPolicy, WorkerPool};
use crate::model::{EvidenceDelta, Mrf};
use crate::sched::SchedChoice;
use anyhow::Result;

/// A backend that recomputes `μ'` for a batch of edges from the live state.
///
/// `out` receives the concatenated new messages (edge k's values at
/// `[k*max_len .. k*max_len + len(e_k)]` with `max_len = mrf.max_domain()`),
/// `residuals[k]` the L2 residual vs. the live message.
pub trait BatchCompute: Sync {
    /// Compute updates for `edges`, writing messages to `out` (stride-packed) and residuals to `residuals`.
    fn compute_batch(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        edges: &[u32],
        out: &mut [f64],
        residuals: &mut [f64],
    );
    /// Backend label for reports.
    fn name(&self) -> &'static str;
}

/// Native reference backend, running the configured data-path kernel.
pub struct NativeBatch {
    /// Data-path kernel for the dense recompute (`RunConfig::kernel`).
    pub kernel: Kernel,
}

impl BatchCompute for NativeBatch {
    fn compute_batch(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        edges: &[u32],
        out: &mut [f64],
        residuals: &mut [f64],
    ) {
        let stride = mrf.max_domain();
        // One gather scratch for the whole batch (no per-edge 64-wide
        // zeroing on the generic path). The residual comes out of the
        // kernel (`residual_l2_against`) — no current-value rebuffering.
        let mut scratch = MsgScratch::new();
        for (k, &e) in edges.iter().enumerate() {
            let slot = &mut out[k * stride..(k + 1) * stride];
            let len = compute_message_with(mrf, msgs, e, slot, &mut scratch, self.kernel);
            residuals[k] = msgs.residual_l2_against(mrf, e, &slot[..len], self.kernel);
        }
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Relaxed residual BP that drains and refreshes tasks in dense batches.
pub struct RelaxedResidualBatched {
    /// Tasks drained per processing round (and the PJRT artifact width).
    pub batch: usize,
}

impl RelaxedResidualBatched {
    fn run_inner(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        cfg: &RunConfig,
        delta: Option<&EvidenceDelta>,
        observer: Option<&dyn crate::exec::RunObserver>,
    ) -> Result<EngineStats> {
        // Resolve the batch backend: PJRT when requested and supported.
        let pjrt = if cfg.use_pjrt && mrf.all_binary() {
            crate::runtime::batch::PjrtBatch::load_default(self.batch).ok()
        } else {
            None
        };
        let native = NativeBatch { kernel: cfg.kernel };
        let backend: &dyn BatchCompute = match &pjrt {
            Some(b) => b,
            None => &native,
        };
        // The fused node-centric refresh bypasses the batch backend; keep
        // the backend path whenever PJRT was explicitly requested and
        // resolved (its dense kernel is the point of that configuration).
        let fused = cfg.fused && pjrt.is_none();
        let policy = match delta {
            None => BatchedPolicy::new(mrf, msgs, cfg, backend, fused),
            Some(d) => BatchedPolicy::new_delta(mrf, msgs, cfg, backend, fused, d),
        };
        Ok(WorkerPool::from_config(cfg, SchedChoice::Relaxed)
            .batch(self.batch.max(1))
            .with_partition(crate::model::partition::for_messages(mrf, cfg))
            .run_observed(&policy, observer))
    }
}

impl Engine for RelaxedResidualBatched {
    fn name(&self) -> String {
        format!("relaxed_residual_batched_{}", self.batch)
    }

    fn run(&self, mrf: &Mrf, msgs: &Messages, cfg: &RunConfig) -> Result<EngineStats> {
        self.run_observed(mrf, msgs, cfg, None)
    }

    fn run_observed(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        cfg: &RunConfig,
        observer: Option<&dyn crate::exec::RunObserver>,
    ) -> Result<EngineStats> {
        self.run_inner(mrf, msgs, cfg, None, observer)
    }

    fn resume(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        cfg: &RunConfig,
        delta: &EvidenceDelta,
        observer: Option<&dyn crate::exec::RunObserver>,
    ) -> Result<EngineStats> {
        self.run_inner(mrf, msgs, cfg, Some(delta), observer)
    }
}

/// Per-worker batch buffers.
pub(crate) struct BatchScratch {
    /// Combined affected-edge set of the processed batch.
    affected: Vec<u32>,
    /// Dense batch output (`affected.len() * stride`).
    out: Vec<f64>,
    /// Per-affected-edge residuals.
    res: Vec<f64>,
    /// Deduplicated destination nodes of the batch (fused path).
    nodes: Vec<u32>,
    /// Fused-kernel prefix/suffix buffers.
    node: NodeScratch,
    /// `(edge, residual)` requeue batch (fused path).
    batch: Vec<(u32, f64)>,
}

/// Relaxed-residual policy whose affected-set refresh runs as one dense
/// batch through a pluggable [`BatchCompute`] backend.
pub(crate) struct BatchedPolicy<'a> {
    mrf: &'a Mrf,
    msgs: &'a Messages,
    la: Lookahead,
    backend: &'a dyn BatchCompute,
    /// `mrf.max_domain()`, hoisted: it is an O(V) scan per call.
    stride: usize,
    eps: f64,
    /// Node-centric fused refresh instead of the dense edge-list backend
    /// (`RunConfig::fused`, forced off when the PJRT backend is live).
    fused: bool,
    /// Delta warm start: seed only the out-edges of these (perturbed)
    /// nodes. `None` = scratch run, full seed.
    seed_nodes: Option<Vec<u32>>,
}

impl<'a> BatchedPolicy<'a> {
    pub(crate) fn new(
        mrf: &'a Mrf,
        msgs: &'a Messages,
        cfg: &RunConfig,
        backend: &'a dyn BatchCompute,
        fused: bool,
    ) -> Self {
        let la = if fused {
            Lookahead::init_fused(mrf, msgs, cfg.kernel)
        } else {
            Lookahead::init(mrf, msgs, cfg.kernel)
        };
        BatchedPolicy {
            mrf,
            msgs,
            la,
            backend,
            stride: mrf.max_domain(),
            eps: cfg.epsilon,
            fused,
            seed_nodes: None,
        }
    }

    /// Warm-start policy over a resident `msgs` state with a delta-primed
    /// lookahead cache (see [`Lookahead::init_delta`]).
    pub(crate) fn new_delta(
        mrf: &'a Mrf,
        msgs: &'a Messages,
        cfg: &RunConfig,
        backend: &'a dyn BatchCompute,
        fused: bool,
        delta: &EvidenceDelta,
    ) -> Self {
        let nodes: Vec<u32> = delta.nodes().collect();
        let la = if fused {
            Lookahead::init_delta_fused(mrf, msgs, cfg.kernel, &nodes)
        } else {
            Lookahead::init_delta(mrf, msgs, cfg.kernel, &nodes)
        };
        BatchedPolicy {
            mrf,
            msgs,
            la,
            backend,
            stride: mrf.max_domain(),
            eps: cfg.epsilon,
            fused,
            seed_nodes: Some(nodes),
        }
    }
}

impl TaskPolicy for BatchedPolicy<'_> {
    type Scratch = BatchScratch;

    fn num_tasks(&self) -> usize {
        self.mrf.num_messages()
    }

    fn make_scratch(&self) -> Self::Scratch {
        BatchScratch {
            affected: Vec::new(),
            out: Vec::new(),
            res: Vec::new(),
            nodes: Vec::new(),
            node: NodeScratch::new(),
            batch: Vec::new(),
        }
    }

    fn seed(&self, ctx: &mut ExecCtx<'_>) {
        match &self.seed_nodes {
            None => {
                for e in 0..self.mrf.num_messages() as u32 {
                    ctx.requeue(e, self.la.residual(e));
                }
            }
            Some(nodes) => {
                // Delta warm start: one shard-grouped batched insert of
                // the re-priced frontier (out-edges of perturbed nodes).
                let mut batch = Vec::new();
                for &i in nodes {
                    for s in self.mrf.graph.slots(i as usize) {
                        let e = self.mrf.graph.adj_out[s];
                        batch.push((e, self.la.residual(e)));
                    }
                }
                ctx.counters.tasks_touched += batch.len() as u64;
                ctx.requeue_batch(&batch);
            }
        }
    }

    fn process(&self, tasks: &[u32], ctx: &mut ExecCtx<'_>, sc: &mut BatchScratch) -> u64 {
        // ---- Commit all claimed updates ----
        for &e in tasks {
            let r = self.la.commit(self.mrf, self.msgs, e);
            ctx.counters.updates += 1;
            if r >= self.eps {
                ctx.counters.useful_updates += 1;
            } else {
                ctx.counters.wasted_pops += 1;
            }
        }

        if self.fused {
            // ---- Node-centric fused refresh of the touched dst nodes ----
            // Each touched node's *whole* out-set is refreshed in one
            // O(deg) pass (the reverse edges beyond the per-task affected
            // union recompute to their current values — their residual is
            // re-derived from ground truth, a strict repair), then the
            // combined (edge, residual) set requeues through one batched
            // insert.
            sc.nodes.clear();
            for &e in tasks {
                sc.nodes.push(self.mrf.graph.edge_dst[e as usize]);
            }
            sc.nodes.sort_unstable();
            sc.nodes.dedup();
            sc.batch.clear();
            for &j in sc.nodes.iter() {
                self.la
                    .refresh_node(self.mrf, self.msgs, j, None, &mut sc.node, &mut sc.batch);
            }
            ctx.counters.refreshes += sc.batch.len() as u64;
            ctx.requeue_batch(&sc.batch);
            return tasks.len() as u64;
        }

        // ---- Batched refresh of the combined affected set ----
        sc.affected.clear();
        for &e in tasks {
            sc.affected.extend(self.la.affected_edges(self.mrf, e));
        }
        sc.affected.sort_unstable();
        sc.affected.dedup();

        let stride = self.stride;
        sc.out.resize(sc.affected.len() * stride, 0.0);
        sc.res.resize(sc.affected.len(), 0.0);
        ctx.counters.refreshes += sc.affected.len() as u64;
        self.backend.compute_batch(self.mrf, self.msgs, &sc.affected, &mut sc.out, &mut sc.res);
        for (k, &e) in sc.affected.iter().enumerate() {
            let len = self.mrf.msg_len(e);
            self.la.store_pending(self.mrf, e, &sc.out[k * stride..k * stride + len], sc.res[k]);
            ctx.requeue(e, sc.res[k]);
        }
        tasks.len() as u64
    }

    fn verify_sweep(&self, ctx: &mut ExecCtx<'_>) -> bool {
        let mut found = false;
        if self.fused {
            let mut sc = NodeScratch::new();
            let mut batch = Vec::new();
            for j in 0..self.mrf.num_nodes() as u32 {
                batch.clear();
                self.la.refresh_node(self.mrf, self.msgs, j, None, &mut sc, &mut batch);
                for &(e, r) in &batch {
                    if ctx.requeue(e, r) {
                        found = true;
                    }
                }
            }
        } else {
            let mut gather = MsgScratch::new();
            for e in 0..self.mrf.num_messages() as u32 {
                let r = self.la.refresh(self.mrf, self.msgs, e, &mut gather);
                if ctx.requeue(e, r) {
                    found = true;
                }
            }
        }
        !found
    }

    fn arena_bytes(&self) -> (u64, u64) {
        let (live_l, live_p) = self.msgs.arena_bytes();
        let (la_l, la_p) = self.la.arena_bytes();
        ((live_l + la_l) as u64, (live_p + la_p) as u64)
    }

    fn final_priority(&self) -> f64 {
        self.la.max_residual()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::{all_marginals, exact_marginals, max_marginal_diff};
    use crate::configio::{AlgorithmSpec, ModelSpec};
    use crate::model::builders;

    #[test]
    fn native_batched_tree_converges() {
        let spec = ModelSpec::Tree { n: 127 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::RelaxedResidualBatched { batch: 16 });
        let stats = RelaxedResidualBatched { batch: 16 }.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);
        for m in bp {
            assert!((m[0] - 0.1).abs() < 1e-4);
        }
    }

    #[test]
    fn batched_oracle_grid_multithreaded() {
        let spec = ModelSpec::Ising { n: 4 };
        let mrf = builders::build(&spec, 3);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::RelaxedResidualBatched { batch: 8 })
            .with_threads(3);
        let stats = RelaxedResidualBatched { batch: 8 }.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);
        let exact = exact_marginals(&mrf, 1 << 20).unwrap();
        assert!(max_marginal_diff(&bp, &exact) < 0.06);
    }

    #[test]
    fn batch_one_equals_relaxed_residual_semantics() {
        let spec = ModelSpec::Ising { n: 6 };
        let mrf = builders::build(&spec, 5);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::RelaxedResidualBatched { batch: 1 });
        let stats = RelaxedResidualBatched { batch: 1 }.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        assert!(stats.final_max_priority < 1e-5);
    }

    #[test]
    fn ldpc_batched_decodes() {
        // Non-binary domains use the native backend automatically.
        let inst = builders::ldpc::build(40, 0.05, 4);
        let msgs = Messages::uniform(&inst.mrf);
        let cfg = RunConfig::new(
            ModelSpec::Ldpc { n: 40, flip_prob: 0.05 },
            AlgorithmSpec::RelaxedResidualBatched { batch: 32 },
        )
        .with_threads(2);
        let stats = RelaxedResidualBatched { batch: 32 }.run(&inst.mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bits = crate::bp::decode_bits(&inst.mrf, &msgs, inst.num_vars);
        assert_eq!(bits, inst.sent);
    }
}
