//! Batched relaxed residual BP — the three-layer extension.
//!
//! Identical scheduling semantics to relaxed residual BP, but each worker
//! drains up to `batch` tasks from the Multiqueue before computing, then
//! performs all lookahead refreshes for the combined affected-edge set as
//! **one dense batch**. The batch compute is pluggable via
//! [`BatchCompute`]:
//!
//! - [`NativeBatch`] — scalar loop (baseline / arbitrary domains);
//! - `runtime::batch::PjrtBatch` — the AOT-compiled JAX/Pallas kernel
//!   executed through the PJRT CPU client (binary models), putting layers
//!   L1/L2 on the request path with Python long gone.
//!
//! Batching amortizes scheduler traffic (one pop ≈ splash's motivation)
//! and exposes SIMD/MXU-shaped work to the kernel layer.

use super::{Engine, EngineStats};
use crate::bp::{compute_message, msg_buf, residual_l2, Lookahead, Messages, MsgSource};
use crate::configio::RunConfig;
use crate::coordinator::{run_workers, Budget, Counters, MetricsReport, Termination};
use crate::model::Mrf;
use crate::sched::{Entry, Multiqueue, Scheduler, TaskStates};
use crate::util::{Timer, Xoshiro256};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};

/// A backend that recomputes `μ'` for a batch of edges from the live state.
///
/// `out` receives the concatenated new messages (edge k's values at
/// `[k*max_len .. k*max_len + len(e_k)]` with `max_len = mrf.max_domain()`),
/// `residuals[k]` the L2 residual vs. the live message.
pub trait BatchCompute: Sync {
    fn compute_batch(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        edges: &[u32],
        out: &mut [f64],
        residuals: &mut [f64],
    );
    fn name(&self) -> &'static str;
}

/// Scalar reference backend.
pub struct NativeBatch;

impl BatchCompute for NativeBatch {
    fn compute_batch(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        edges: &[u32],
        out: &mut [f64],
        residuals: &mut [f64],
    ) {
        let stride = mrf.max_domain();
        let mut cur = msg_buf();
        for (k, &e) in edges.iter().enumerate() {
            let slot = &mut out[k * stride..(k + 1) * stride];
            let len = compute_message(mrf, msgs, e, slot);
            msgs.read_msg(mrf, e, &mut cur);
            residuals[k] = residual_l2(&slot[..len], &cur[..len]);
        }
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

pub struct RelaxedResidualBatched {
    pub batch: usize,
}

impl Engine for RelaxedResidualBatched {
    fn name(&self) -> String {
        format!("relaxed_residual_batched_{}", self.batch)
    }

    fn run(&self, mrf: &Mrf, msgs: &Messages, cfg: &RunConfig) -> Result<EngineStats> {
        // Resolve the batch backend: PJRT when requested and supported.
        let pjrt = if cfg.use_pjrt && mrf.all_binary() {
            crate::runtime::batch::PjrtBatch::load_default(self.batch).ok()
        } else {
            None
        };
        match &pjrt {
            Some(b) => run_batched(mrf, msgs, cfg, self.batch, b),
            None => run_batched(mrf, msgs, cfg, self.batch, &NativeBatch),
        }
    }
}

pub(crate) fn run_batched(
    mrf: &Mrf,
    msgs: &Messages,
    cfg: &RunConfig,
    batch: usize,
    backend: &dyn BatchCompute,
) -> Result<EngineStats> {
    let timer = Timer::start();
    let budget = Budget::new(cfg.time_limit_secs, cfg.max_updates);
    let eps = cfg.epsilon;
    let batch = batch.max(1);
    let stride = mrf.max_domain();

    let sched = Multiqueue::for_threads(cfg.threads, cfg.queues_per_thread);
    let la = Lookahead::init(mrf, msgs);
    let ts = TaskStates::new(mrf.num_messages());
    let term = Termination::new();
    let timed_out = AtomicBool::new(false);

    {
        let mut rng = Xoshiro256::stream(cfg.seed, 0xBA7C);
        for e in 0..mrf.num_messages() as u32 {
            let r = la.residual(e);
            if r >= eps {
                term.before_insert();
                sched.insert(Entry { prio: r, task: e, epoch: ts.epoch(e) }, &mut rng);
            }
        }
    }

    let per_thread = run_workers(cfg.threads, |tid| {
        let mut rng = Xoshiro256::stream(cfg.seed, 5000 + tid as u64);
        let mut c = Counters::default();
        let mut claimed: Vec<u32> = Vec::with_capacity(batch);
        let mut affected: Vec<u32> = Vec::new();
        let mut out = vec![0.0f64; 0];
        let mut res = vec![0.0f64; 0];
        let mut since_flush: u64 = 0;

        while !term.is_done() {
            // ---- Drain up to `batch` valid tasks ----
            claimed.clear();
            term.enter();
            while claimed.len() < batch {
                match sched.pop(&mut rng) {
                    Some(ent) => {
                        term.after_pop();
                        c.pops += 1;
                        if ent.epoch != ts.epoch(ent.task) {
                            c.stale_pops += 1;
                            continue;
                        }
                        if !ts.try_claim(ent.task, ent.epoch) {
                            c.claim_failures += 1;
                            continue;
                        }
                        claimed.push(ent.task);
                    }
                    None => break,
                }
            }
            if claimed.is_empty() {
                term.exit();
                if term.quiescent() {
                    term.try_verify(|| {
                        let mut found = false;
                        for e in 0..mrf.num_messages() as u32 {
                            let r = la.refresh(mrf, msgs, e);
                            if r >= eps {
                                let epoch = ts.bump(e);
                                term.before_insert();
                                sched.insert(Entry { prio: r, task: e, epoch }, &mut rng);
                                found = true;
                            }
                        }
                        !found
                    });
                } else {
                    std::thread::yield_now();
                    if budget.expired(term.global_updates.load(Ordering::Relaxed)) {
                        timed_out.store(true, Ordering::Release);
                        term.set_done();
                    }
                }
                continue;
            }

            // ---- Commit all claimed updates ----
            for &e in &claimed {
                let r = la.commit(mrf, msgs, e);
                c.updates += 1;
                since_flush += 1;
                if r >= eps {
                    c.useful_updates += 1;
                } else {
                    c.wasted_pops += 1;
                }
            }

            // ---- Batched refresh of the combined affected set ----
            affected.clear();
            for &e in &claimed {
                let j = mrf.graph.edge_dst[e as usize] as usize;
                let rev = mrf.graph.reverse(e);
                for s in mrf.graph.slots(j) {
                    let k = mrf.graph.adj_out[s];
                    if k != rev {
                        affected.push(k);
                    }
                }
            }
            affected.sort_unstable();
            affected.dedup();

            out.resize(affected.len() * stride, 0.0);
            res.resize(affected.len(), 0.0);
            backend.compute_batch(mrf, msgs, &affected, &mut out, &mut res);
            for (k, &e) in affected.iter().enumerate() {
                let len = mrf.msg_len(e);
                la.store_pending(mrf, e, &out[k * stride..k * stride + len], res[k]);
                let epoch = ts.bump(e);
                if res[k] >= eps {
                    term.before_insert();
                    sched.insert(Entry { prio: res[k], task: e, epoch }, &mut rng);
                    c.inserts += 1;
                }
            }
            for &e in &claimed {
                ts.release(e);
            }
            term.exit();

            if since_flush >= 256 {
                let g = term.global_updates.fetch_add(since_flush, Ordering::Relaxed)
                    + since_flush;
                since_flush = 0;
                if budget.expired(g) {
                    timed_out.store(true, Ordering::Release);
                    term.set_done();
                }
            }
        }
        c
    });

    let final_max = la.max_residual();
    Ok(EngineStats {
        converged: !timed_out.load(Ordering::Acquire),
        wall_secs: timer.elapsed_secs(),
        metrics: MetricsReport::aggregate(&per_thread),
        final_max_priority: final_max,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::{all_marginals, exact_marginals, max_marginal_diff};
    use crate::configio::{AlgorithmSpec, ModelSpec};
    use crate::model::builders;

    #[test]
    fn native_batched_tree_converges() {
        let spec = ModelSpec::Tree { n: 127 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::RelaxedResidualBatched { batch: 16 });
        let stats = RelaxedResidualBatched { batch: 16 }.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);
        for m in bp {
            assert!((m[0] - 0.1).abs() < 1e-4);
        }
    }

    #[test]
    fn batched_oracle_grid_multithreaded() {
        let spec = ModelSpec::Ising { n: 4 };
        let mrf = builders::build(&spec, 3);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::RelaxedResidualBatched { batch: 8 })
            .with_threads(3);
        let stats = RelaxedResidualBatched { batch: 8 }.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);
        let exact = exact_marginals(&mrf, 1 << 20).unwrap();
        assert!(max_marginal_diff(&bp, &exact) < 0.06);
    }

    #[test]
    fn batch_one_equals_relaxed_residual_semantics() {
        let spec = ModelSpec::Ising { n: 6 };
        let mrf = builders::build(&spec, 5);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::RelaxedResidualBatched { batch: 1 });
        let stats = RelaxedResidualBatched { batch: 1 }.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        assert!(stats.final_max_priority < 1e-5);
    }

    #[test]
    fn ldpc_batched_decodes() {
        // Non-binary domains use the native backend automatically.
        let inst = builders::ldpc::build(40, 0.05, 4);
        let msgs = Messages::uniform(&inst.mrf);
        let cfg = RunConfig::new(
            ModelSpec::Ldpc { n: 40, flip_prob: 0.05 },
            AlgorithmSpec::RelaxedResidualBatched { batch: 32 },
        )
        .with_threads(2);
        let stats = RelaxedResidualBatched { batch: 32 }.run(&inst.mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bits = crate::bp::decode_bits(&inst.mrf, &msgs, inst.num_vars);
        assert_eq!(bits, inst.sent);
    }
}
