//! Randomized synchronous BP (Van der Merwe–Joseph–Gopalakrishnan, HPEC
//! 2019), designed for GPUs — the paper's Appendix B.2 baseline.
//!
//! Each round updates a *subset* of messages synchronously. When the run
//! is converging (the count of unconverged messages dropped since the last
//! round), all unconverged messages are updated; when it is converging
//! *slowly*, only a random fraction `lowP` of them is updated — the random
//! subsetting injects the schedule noise that lets the algorithm escape
//! cyclic non-convergent behavior. (On CPUs the per-round residual scans
//! make this uncompetitive, which is the paper's point in Table 7.)

use super::{Engine, EngineStats};
use crate::bp::{Lookahead, Messages, MsgScratch};
use crate::configio::RunConfig;
use crate::coordinator::{run_workers, Budget, Counters, MetricsReport};
use crate::model::Mrf;
use crate::util::{Timer, Xoshiro256};
use anyhow::Result;

/// Van der Merwe randomized synchronous BP.
pub struct RandomSynch {
    /// Fraction of unconverged messages updated in slow rounds.
    pub low_p: f64,
}

impl Engine for RandomSynch {
    fn name(&self) -> String {
        format!("random_synch_{}", self.low_p)
    }

    fn run(&self, mrf: &Mrf, msgs: &Messages, cfg: &RunConfig) -> Result<EngineStats> {
        let timer = Timer::start();
        let budget = Budget::new(cfg.time_limit_secs, cfg.max_updates);
        let eps = cfg.epsilon;
        let threads = cfg.threads.max(1);
        let me = mrf.num_messages();

        let la = Lookahead::init(mrf, msgs, cfg.kernel);
        let mut rng = Xoshiro256::stream(cfg.seed, 0xBEEF);
        let mut total = Counters::default();
        let (live_l, live_p) = msgs.arena_bytes();
        let (la_l, la_p) = la.arena_bytes();
        total.msg_bytes_logical = (live_l + la_l) as u64;
        total.msg_bytes_padded = (live_p + la_p) as u64;
        let mut prev_unconverged = usize::MAX;
        let mut converged_flag = true;
        let mut global: u64 = 0;

        loop {
            // Unconverged messages under the current residuals.
            let unconverged: Vec<u32> = (0..me as u32).filter(|&e| la.residual(e) >= eps).collect();
            if unconverged.is_empty() {
                break;
            }
            // Slow convergence → random lowP subset; otherwise all.
            let slow = unconverged.len() >= prev_unconverged;
            prev_unconverged = unconverged.len();
            let selected: Vec<u32> = if slow {
                let k = ((unconverged.len() as f64 * self.low_p).ceil() as usize).max(1);
                rng.sample_indices(unconverged.len(), k)
                    .into_iter()
                    .map(|i| unconverged[i])
                    .collect()
            } else {
                unconverged
            };

            // Synchronous block update of the selection.
            let chunk = selected.len().div_ceil(threads);
            let per_thread = run_workers(threads, |tid| {
                let mut c = Counters::default();
                let lo = (tid * chunk).min(selected.len());
                let hi = ((tid + 1) * chunk).min(selected.len());
                for &e in &selected[lo..hi] {
                    let r = la.residual(e);
                    la.commit(mrf, msgs, e);
                    c.updates += 1;
                    if r >= eps {
                        c.useful_updates += 1;
                    }
                }
                c
            });
            let mut round_updates = 0;
            for c in &per_thread {
                round_updates += c.updates;
                total.add(c);
            }
            total.rounds += 1;

            // Refresh residuals of affected edges (out-edges of every dst).
            let mut dsts: Vec<u32> =
                selected.iter().map(|&e| mrf.graph.edge_dst[e as usize]).collect();
            dsts.sort_unstable();
            dsts.dedup();
            let chunk2 = dsts.len().div_ceil(threads);
            run_workers(threads, |tid| {
                let mut gather = MsgScratch::new();
                let lo = (tid * chunk2).min(dsts.len());
                let hi = ((tid + 1) * chunk2).min(dsts.len());
                for &j in &dsts[lo..hi] {
                    for s in mrf.graph.slots(j as usize) {
                        la.refresh(mrf, msgs, mrf.graph.adj_out[s], &mut gather);
                    }
                }
            });

            global += round_updates;
            if budget.expired(global) {
                converged_flag = false;
                break;
            }
        }

        let final_max = la.max_residual();
        Ok(EngineStats {
            converged: converged_flag && final_max < eps,
            wall_secs: timer.elapsed_secs(),
            metrics: MetricsReport::aggregate(&[total]),
            final_max_priority: final_max,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::{all_marginals, max_marginal_diff};
    use crate::configio::{AlgorithmSpec, ModelSpec};
    use crate::model::builders;

    #[test]
    fn converges_on_tree() {
        let spec = ModelSpec::Tree { n: 31 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg =
            RunConfig::new(spec, AlgorithmSpec::RandomSynchronous { low_p: 0.4 }).with_threads(2);
        let stats = RandomSynch { low_p: 0.4 }.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);
        for m in bp {
            assert!((m[0] - 0.1).abs() < 1e-4);
        }
    }

    #[test]
    fn matches_residual_fixed_point_on_small_grid() {
        // Compare against sequential residual (same BP fixed point) rather
        // than the exact oracle — the loopy-BP bias on tight grids is
        // schedule-independent but can exceed oracle tolerances.
        let spec = ModelSpec::Ising { n: 3 };
        let mrf = builders::build(&spec, 6);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::RandomSynchronous { low_p: 0.7 });
        let stats = RandomSynch { low_p: 0.7 }.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);

        let mrf2 = builders::build(&spec, 6);
        let msgs2 = Messages::uniform(&mrf2);
        let cfg2 = RunConfig::new(spec, AlgorithmSpec::SequentialResidual).with_seed(6);
        let s2 = crate::engines::sequential::SequentialResidual
            .run(&mrf2, &msgs2, &cfg2)
            .unwrap();
        assert!(s2.converged);
        let seq = all_marginals(&mrf2, &msgs2);
        assert!(
            max_marginal_diff(&bp, &seq) < 1e-2,
            "diff = {}",
            max_marginal_diff(&bp, &seq)
        );
    }

    #[test]
    fn low_p_bounds_selection() {
        // With low_p = 0.1 updates per round in slow phases are ≤ ~10% of
        // unconverged messages; just verify the run completes and counts.
        let spec = ModelSpec::Potts { n: 4, q: 3 };
        let mrf = builders::build(&spec, 8);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::RandomSynchronous { low_p: 0.1 });
        let stats = RandomSynch { low_p: 0.1 }.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        assert!(stats.metrics.total.rounds >= 1);
    }
}
