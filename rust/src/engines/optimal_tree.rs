//! The Appendix-A optimal tree schedule, exact and relaxed.
//!
//! On a tree, BP converges after each message is updated exactly once in
//! the two-phase order: leaves→root, then root→leaves. Appendix A encodes
//! this as a priority function needing O(1) metadata per message:
//!
//! 1. leaf out-messages start with priority `n`; everything else 0;
//! 2. an executed message's priority drops to 0;
//! 3. once all `μ_{k→i}, k ∈ N(i)\{j}` have been executed with non-zero
//!    priority, `μ_{i→j}`'s priority becomes `min(their priorities) − 1`.
//!
//! Claim 4: the relaxed version performs `O(n + q²·H)` updates. To exercise
//! exactly the analytical model, *all* messages live in the scheduler for
//! the whole run (zero-priority pops are the *wasted updates* the claim
//! counts — the pool runs with an insert threshold of `−∞`), and the run
//! ends when all `2(n−1)` messages have had their useful
//! (non-zero-priority) update.

use super::{Engine, EngineStats};
use crate::bp::{compute_message_with, msg_buf, Kernel, Messages, MsgBuf, MsgScratch};
use crate::configio::RunConfig;
use crate::exec::{ExecCtx, TaskPolicy, WorkerPool};
use crate::model::Mrf;
use crate::sched::SchedChoice;
use crate::util::AtomicF64;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// The Appendix-A optimal tree schedule.
pub struct OptimalTree {
    /// Run on the Multiqueue instead of the exact queue.
    pub relaxed: bool,
}

impl Engine for OptimalTree {
    fn name(&self) -> String {
        if self.relaxed {
            "relaxed_optimal_tree".into()
        } else {
            "optimal_tree".into()
        }
    }

    fn run(&self, mrf: &Mrf, msgs: &Messages, cfg: &RunConfig) -> Result<EngineStats> {
        self.run_observed(mrf, msgs, cfg, None)
    }

    fn run_observed(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        cfg: &RunConfig,
        observer: Option<&dyn crate::exec::RunObserver>,
    ) -> Result<EngineStats> {
        // Must be a tree: |E| = |V| − 1 and connected.
        if mrf.num_messages() != 2 * (mrf.num_nodes() - 1) {
            bail!("optimal_tree engine requires a tree model");
        }
        let choice = if self.relaxed { SchedChoice::Relaxed } else { SchedChoice::Exact };
        let policy = OptimalTreePolicy::new(mrf, msgs, cfg.kernel);
        Ok(WorkerPool::from_config(cfg, choice)
            .insert_threshold(f64::NEG_INFINITY)
            .with_partition(crate::model::partition::for_messages(mrf, cfg))
            .run_observed(&policy, observer))
    }
}

/// Message-task policy implementing the Appendix-A priority function. The
/// pool keeps every message resident (threshold `−∞`); completion is the
/// useful-update target, not quiescence.
pub(crate) struct OptimalTreePolicy<'a> {
    mrf: &'a Mrf,
    msgs: &'a Messages,
    /// Current Appendix-A priority of each message.
    prio: Vec<AtomicF64>,
    /// Messages `μ_{k→i}` (k ≠ j) still to fire before (i→j) activates.
    remaining: Vec<AtomicU32>,
    /// Min priority among the fired in-messages (rule 3).
    min_in_prio: Vec<AtomicF64>,
    useful: AtomicU64,
    target: u64,
    /// Data-path kernel (`RunConfig::kernel`).
    kernel: Kernel,
}

impl<'a> OptimalTreePolicy<'a> {
    pub(crate) fn new(mrf: &'a Mrf, msgs: &'a Messages, kernel: Kernel) -> Self {
        let me = mrf.num_messages();
        OptimalTreePolicy {
            mrf,
            msgs,
            prio: (0..me).map(|_| AtomicF64::new(0.0)).collect(),
            remaining: (0..me)
                .map(|e| {
                    let i = mrf.graph.edge_src[e] as usize;
                    AtomicU32::new((mrf.graph.degree(i) - 1) as u32)
                })
                .collect(),
            min_in_prio: (0..me).map(|_| AtomicF64::new(f64::MAX)).collect(),
            useful: AtomicU64::new(0),
            target: me as u64,
            kernel,
        }
    }
}

impl TaskPolicy for OptimalTreePolicy<'_> {
    type Scratch = (MsgBuf, MsgScratch);

    fn num_tasks(&self) -> usize {
        self.mrf.num_messages()
    }

    fn make_scratch(&self) -> Self::Scratch {
        (msg_buf(), MsgScratch::new())
    }

    fn seed(&self, ctx: &mut ExecCtx<'_>) {
        // ALL messages enter the scheduler; leaf out-edges at n.
        let n = self.mrf.num_nodes();
        for e in 0..self.mrf.num_messages() as u32 {
            let i = self.mrf.graph.edge_src[e as usize] as usize;
            let p = if self.mrf.graph.degree(i) == 1 { n as f64 } else { 0.0 };
            self.prio[e as usize].store(p);
            ctx.requeue(e, p);
        }
    }

    fn process(
        &self,
        tasks: &[u32],
        ctx: &mut ExecCtx<'_>,
        scratch: &mut (MsgBuf, MsgScratch),
    ) -> u64 {
        let (buf, gather) = scratch;
        for &e in tasks {
            let p = self.prio[e as usize].load();
            // Execute the update (even with priority 0 — those are the
            // wasted updates of Claim 4).
            let len = compute_message_with(self.mrf, self.msgs, e, buf, gather, self.kernel);
            self.msgs.write_msg(self.mrf, e, &buf[..len]);
            ctx.counters.updates += 1;

            if p > 0.0 {
                ctx.counters.useful_updates += 1;
                self.prio[e as usize].store(0.0);
                // Propagate rule (3) to out-edges of dst.
                let j = self.mrf.graph.edge_dst[e as usize] as usize;
                let rev = self.mrf.graph.reverse(e);
                for s in self.mrf.graph.slots(j) {
                    let k = self.mrf.graph.adj_out[s];
                    if k == rev {
                        continue;
                    }
                    self.min_in_prio[k as usize].fetch_min(p);
                    if self.remaining[k as usize].fetch_sub(1, Ordering::AcqRel) == 1 {
                        let newp = self.min_in_prio[k as usize].load() - 1.0;
                        self.prio[k as usize].store(newp);
                        ctx.requeue(k, newp);
                    }
                }
                if self.useful.fetch_add(1, Ordering::AcqRel) + 1 == self.target {
                    ctx.finish();
                }
            } else {
                ctx.counters.wasted_pops += 1;
            }
            // Re-insert with priority 0: the task stays in the scheduler
            // pool per the analytical model (threshold is −∞).
            ctx.requeue(e, 0.0);
        }
        tasks.len() as u64
    }

    fn verify_sweep(&self, _: &mut ExecCtx<'_>) -> bool {
        // Every task is always resident, so the pool cannot quiesce while
        // useful updates remain; this is only reachable on the degenerate
        // zero-message tree.
        self.useful.load(Ordering::Acquire) == self.target
    }

    fn converged(&self, _timed_out: bool) -> bool {
        // Completion is the analytical model's criterion: every message
        // got its one useful update.
        self.useful.load(Ordering::Acquire) == self.target
    }

    fn arena_bytes(&self) -> (u64, u64) {
        // No lookahead cache: the live arenas are the whole footprint.
        let (l, p) = self.msgs.arena_bytes();
        (l as u64, p as u64)
    }

    fn final_priority(&self) -> f64 {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::all_marginals;
    use crate::configio::{AlgorithmSpec, ModelSpec};
    use crate::model::builders;

    #[test]
    fn exact_schedule_does_minimum_work() {
        let spec = ModelSpec::Tree { n: 63 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::OptimalTree);
        let stats = OptimalTree { relaxed: false }.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        assert_eq!(stats.metrics.total.useful_updates, 124); // 2(n−1)
        // The exact scheduler never pops a zero before a positive exists…
        // (zero-priority re-inserts can surface only after all positives
        // drain, at which point the run is over).
        assert_eq!(stats.metrics.total.updates, 124);
        let bp = all_marginals(&mrf, &msgs);
        for m in bp {
            assert!((m[0] - 0.1).abs() < 1e-9);
        }
    }

    #[test]
    fn relaxed_schedule_bounded_waste() {
        let spec = ModelSpec::Tree { n: 255 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::RelaxedOptimalTree).with_threads(2);
        let stats = OptimalTree { relaxed: true }.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        assert_eq!(stats.metrics.total.useful_updates, 508);
        // Claim 4: waste is O(q²·H), far below O(n·q) here.
        let waste = stats.metrics.total.updates - stats.metrics.total.useful_updates;
        assert!(waste < 5080, "waste={waste}");
    }

    #[test]
    fn rejects_non_tree() {
        let spec = ModelSpec::Ising { n: 3 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::OptimalTree);
        assert!(OptimalTree { relaxed: false }.run(&mrf, &msgs, &cfg).is_err());
    }

    #[test]
    fn exact_marginals_on_path() {
        let spec = ModelSpec::Path { n: 10 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::OptimalTree);
        let stats = OptimalTree { relaxed: false }.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);
        let exact = crate::bp::exact_marginals(&mrf, 1 << 20).unwrap();
        assert!(crate::bp::max_marginal_diff(&bp, &exact) < 1e-9);
    }
}
