//! The Appendix-A optimal tree schedule, exact and relaxed.
//!
//! On a tree, BP converges after each message is updated exactly once in
//! the two-phase order: leaves→root, then root→leaves. Appendix A encodes
//! this as a priority function needing O(1) metadata per message:
//!
//! 1. leaf out-messages start with priority `n`; everything else 0;
//! 2. an executed message's priority drops to 0;
//! 3. once all `μ_{k→i}, k ∈ N(i)\{j}` have been executed with non-zero
//!    priority, `μ_{i→j}`'s priority becomes `min(their priorities) − 1`.
//!
//! Claim 4: the relaxed version performs `O(n + q²·H)` updates. To exercise
//! exactly the analytical model, *all* messages live in the scheduler for
//! the whole run (zero-priority pops are the *wasted updates* the claim
//! counts), and the run ends when all `2(n−1)` messages have had their
//! useful (non-zero-priority) update.

use super::{Engine, EngineStats};
use crate::bp::{compute_message, msg_buf, Messages};
use crate::configio::RunConfig;
use crate::coordinator::{run_workers, Budget, Counters, MetricsReport, Termination};
use crate::model::Mrf;
use crate::sched::{Entry, ExactQueue, Multiqueue, Scheduler, TaskStates};
use crate::util::{AtomicF64, Timer, Xoshiro256};
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};

pub struct OptimalTree {
    pub relaxed: bool,
}

impl Engine for OptimalTree {
    fn name(&self) -> String {
        if self.relaxed {
            "relaxed_optimal_tree".into()
        } else {
            "optimal_tree".into()
        }
    }

    fn run(&self, mrf: &Mrf, msgs: &Messages, cfg: &RunConfig) -> Result<EngineStats> {
        // Must be a tree: |E| = |V| − 1 and connected.
        let me = mrf.num_messages();
        if me != 2 * (mrf.num_nodes() - 1) {
            bail!("optimal_tree engine requires a tree model");
        }
        let timer = Timer::start();
        let budget = Budget::new(cfg.time_limit_secs, cfg.max_updates);
        let n = mrf.num_nodes();

        let sched: Box<dyn Scheduler> = if self.relaxed {
            Box::new(Multiqueue::for_threads(cfg.threads, cfg.queues_per_thread))
        } else {
            Box::new(ExactQueue::with_capacity(me))
        };
        let sched = sched.as_ref();

        // Per-message metadata.
        let prio: Vec<AtomicF64> = (0..me).map(|_| AtomicF64::new(0.0)).collect();
        // Messages μ_{k→i} (k ≠ j) still to fire before (i→j) activates.
        let remaining: Vec<AtomicU32> = (0..me)
            .map(|e| {
                let i = mrf.graph.edge_src[e] as usize;
                AtomicU32::new((mrf.graph.degree(i) - 1) as u32)
            })
            .collect();
        let min_in_prio: Vec<AtomicF64> = (0..me).map(|_| AtomicF64::new(f64::MAX)).collect();

        let ts = TaskStates::new(me);
        let term = Termination::new();
        let timed_out = AtomicBool::new(false);
        let useful_count = AtomicU64::new(0);
        let target_useful = me as u64;

        // Seed: ALL messages enter the scheduler; leaf out-edges at n.
        {
            let mut rng = Xoshiro256::stream(cfg.seed, 0x0CEA);
            for e in 0..me as u32 {
                let i = mrf.graph.edge_src[e as usize] as usize;
                let p = if mrf.graph.degree(i) == 1 { n as f64 } else { 0.0 };
                prio[e as usize].store(p);
                term.before_insert();
                sched.insert(Entry { prio: p, task: e, epoch: ts.epoch(e) }, &mut rng);
            }
        }

        let per_thread = run_workers(cfg.threads, |tid| {
            let mut rng = Xoshiro256::stream(cfg.seed, 4000 + tid as u64);
            let mut c = Counters::default();
            let mut buf = msg_buf();
            let mut since_flush: u64 = 0;

            while !term.is_done() {
                term.enter();
                match sched.pop(&mut rng) {
                    Some(ent) => {
                        term.after_pop();
                        c.pops += 1;
                        if ent.epoch != ts.epoch(ent.task) {
                            c.stale_pops += 1;
                            term.exit();
                            continue;
                        }
                        if !ts.try_claim(ent.task, ent.epoch) {
                            c.claim_failures += 1;
                            term.exit();
                            continue;
                        }
                        let e = ent.task;
                        let p = prio[e as usize].load();
                        // Execute the update (even with priority 0 — those
                        // are the wasted updates of Claim 4).
                        let len = compute_message(mrf, msgs, e, &mut buf);
                        msgs.write_msg(mrf, e, &buf[..len]);
                        c.updates += 1;
                        since_flush += 1;

                        if p > 0.0 {
                            c.useful_updates += 1;
                            prio[e as usize].store(0.0);
                            // Propagate rule (3) to out-edges of dst.
                            let j = mrf.graph.edge_dst[e as usize] as usize;
                            let rev = mrf.graph.reverse(e);
                            for s in mrf.graph.slots(j) {
                                let k = mrf.graph.adj_out[s];
                                if k == rev {
                                    continue;
                                }
                                min_in_prio[k as usize].fetch_min(p);
                                if remaining[k as usize].fetch_sub(1, Ordering::AcqRel) == 1 {
                                    let newp = min_in_prio[k as usize].load() - 1.0;
                                    prio[k as usize].store(newp);
                                    let epoch = ts.bump(k);
                                    term.before_insert();
                                    sched.insert(
                                        Entry { prio: newp, task: k, epoch },
                                        &mut rng,
                                    );
                                    c.inserts += 1;
                                }
                            }
                            let done =
                                useful_count.fetch_add(1, Ordering::AcqRel) + 1 == target_useful;
                            if done {
                                term.set_done();
                            }
                            // Re-insert with priority 0: the task stays in
                            // the scheduler pool per the analytical model.
                            let epoch = ts.bump(e);
                            term.before_insert();
                            sched.insert(Entry { prio: 0.0, task: e, epoch }, &mut rng);
                        } else {
                            c.wasted_pops += 1;
                            // Wasted update: put it straight back.
                            let epoch = ts.bump(e);
                            term.before_insert();
                            sched.insert(Entry { prio: 0.0, task: e, epoch }, &mut rng);
                        }
                        ts.release(e);
                        term.exit();

                        if since_flush >= 256 {
                            let g = term
                                .global_updates
                                .fetch_add(since_flush, Ordering::Relaxed)
                                + since_flush;
                            since_flush = 0;
                            if budget.expired(g) {
                                timed_out.store(true, Ordering::Release);
                                term.set_done();
                            }
                        }
                    }
                    None => {
                        term.exit();
                        // The pool always holds every task; an empty pop can
                        // only race with other pops. Spin.
                        std::thread::yield_now();
                        if budget.expired(term.global_updates.load(Ordering::Relaxed)) {
                            timed_out.store(true, Ordering::Release);
                            term.set_done();
                        }
                    }
                }
            }
            c
        });

        let useful = useful_count.load(Ordering::Acquire);
        Ok(EngineStats {
            converged: useful == target_useful,
            wall_secs: timer.elapsed_secs(),
            metrics: MetricsReport::aggregate(&per_thread),
            final_max_priority: 0.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::all_marginals;
    use crate::configio::{AlgorithmSpec, ModelSpec};
    use crate::model::builders;

    #[test]
    fn exact_schedule_does_minimum_work() {
        let spec = ModelSpec::Tree { n: 63 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::OptimalTree);
        let stats = OptimalTree { relaxed: false }.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        assert_eq!(stats.metrics.total.useful_updates, 124); // 2(n−1)
        // The exact scheduler never pops a zero before a positive exists…
        // (zero-priority re-inserts can surface only after all positives
        // drain, at which point the run is over).
        assert_eq!(stats.metrics.total.updates, 124);
        let bp = all_marginals(&mrf, &msgs);
        for m in bp {
            assert!((m[0] - 0.1).abs() < 1e-9);
        }
    }

    #[test]
    fn relaxed_schedule_bounded_waste() {
        let spec = ModelSpec::Tree { n: 255 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::RelaxedOptimalTree).with_threads(2);
        let stats = OptimalTree { relaxed: true }.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        assert_eq!(stats.metrics.total.useful_updates, 508);
        // Claim 4: waste is O(q²·H), far below O(n·q) here.
        let waste = stats.metrics.total.updates - stats.metrics.total.useful_updates;
        assert!(waste < 5080, "waste={waste}");
    }

    #[test]
    fn rejects_non_tree() {
        let spec = ModelSpec::Ising { n: 3 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::OptimalTree);
        assert!(OptimalTree { relaxed: false }.run(&mrf, &msgs, &cfg).is_err());
    }

    #[test]
    fn exact_marginals_on_path() {
        let spec = ModelSpec::Path { n: 10 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::OptimalTree);
        let stats = OptimalTree { relaxed: false }.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);
        let exact = crate::bp::exact_marginals(&mrf, 1 << 20).unwrap();
        assert!(crate::bp::max_marginal_diff(&bp, &exact) < 1e-9);
    }
}
