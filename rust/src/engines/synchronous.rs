//! Synchronous (round-based) belief propagation.
//!
//! Every round recomputes all messages from the previous round's values —
//! the trivially parallel schedule. Workers stay alive across rounds and
//! meet at a barrier; edges are partitioned statically. Double-buffered:
//! round `r` reads buffer `r mod 2` and writes the other one.
//!
//! When `cfg.use_pjrt` is set and the model is an all-binary grid, the
//! per-round dense sweep is instead executed by the AOT-compiled JAX/Pallas
//! artifact through the PJRT runtime (see `runtime::grid`), demonstrating
//! the three-layer hot path. The native path below is the fallback for
//! arbitrary topologies.

use super::{Engine, EngineStats};
use crate::bp::{compute_message_with, msg_buf, Messages, MsgScratch, MsgSource};
use crate::configio::RunConfig;
use crate::coordinator::{run_workers, Budget, Counters, MetricsReport};
use crate::model::Mrf;
use crate::util::{AtomicF64, Timer};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Barrier;

/// Round-based synchronous BP (parallel over message chunks).
pub struct Synchronous;

/// Shared round-control block.
struct Ctrl {
    done: AtomicBool,
    timed_out: AtomicBool,
    round: AtomicU64,
    max_diff: AtomicF64,
    result_parity: AtomicU64,
}

impl Engine for Synchronous {
    fn name(&self) -> String {
        "synch".into()
    }

    fn run(&self, mrf: &Mrf, msgs: &Messages, cfg: &RunConfig) -> Result<EngineStats> {
        // Three-layer hot path: grid models can run each round through the
        // AOT JAX/Pallas sweep on the PJRT CPU client.
        if cfg.use_pjrt {
            match crate::runtime::grid::run_sync_pjrt(mrf, msgs, cfg) {
                Ok(stats) => return Ok(stats),
                Err(e) => eprintln!("[synch] PJRT path unavailable ({e}); native fallback"),
            }
        }
        let timer = Timer::start();
        let budget = Budget::new(cfg.time_limit_secs, cfg.max_updates);
        let eps = cfg.epsilon;
        let threads = cfg.threads.max(1);
        let me = mrf.num_messages();

        // Double buffers; parity 0 holds the initial state. `uniform_like`
        // mirrors the caller's storage precision, so an f32 run
        // double-buffers in f32 too.
        let bufs = [Messages::uniform_like(mrf, msgs), Messages::uniform_like(mrf, msgs)];
        bufs[0].restore(&msgs.snapshot());
        let (l0, p0) = bufs[0].arena_bytes();
        let (l1, p1) = bufs[1].arena_bytes();
        let (arena_logical, arena_padded) = ((l0 + l1) as u64, (p0 + p1) as u64);

        let ctrl = Ctrl {
            done: AtomicBool::new(me == 0),
            timed_out: AtomicBool::new(false),
            round: AtomicU64::new(0),
            max_diff: AtomicF64::new(0.0),
            result_parity: AtomicU64::new(0),
        };
        let barrier = Barrier::new(threads);

        // Static edge partition.
        let chunk = me.div_ceil(threads);

        let per_thread = run_workers(threads, |tid| {
            let mut c = Counters::default();
            c.msg_bytes_logical = arena_logical;
            c.msg_bytes_padded = arena_padded;
            let lo = (tid * chunk).min(me);
            let hi = ((tid + 1) * chunk).min(me);
            let mut new = msg_buf();
            let mut gather = MsgScratch::new();
            let kernel = cfg.kernel;

            loop {
                barrier.wait();
                if ctrl.done.load(Ordering::Acquire) {
                    break;
                }
                let r = ctrl.round.load(Ordering::Acquire);
                let src = &bufs[(r % 2) as usize];
                let dst = &bufs[((r + 1) % 2) as usize];
                let mut local_max = 0.0f64;
                for e in lo as u32..hi as u32 {
                    let len = compute_message_with(mrf, src, e, &mut new, &mut gather, kernel);
                    // In-kernel residual against the read buffer — no
                    // per-edge current-value rebuffering.
                    let res = src.residual_l2_against(mrf, e, &new[..len], kernel);
                    local_max = local_max.max(res);
                    if kernel.is_simd() {
                        dst.write_msg_bulk(mrf, e, &new[..len]);
                    } else {
                        dst.write_msg(mrf, e, &new[..len]);
                    }
                    c.updates += 1;
                }
                ctrl.max_diff.fetch_max(local_max);
                if tid == 0 {
                    c.rounds += 1; // rounds are global, count once
                }
                barrier.wait();
                if tid == 0 {
                    let diff = ctrl.max_diff.load();
                    let total_updates = (r + 1) * me as u64;
                    ctrl.result_parity.store((r + 1) % 2, Ordering::Release);
                    if diff < eps {
                        ctrl.done.store(true, Ordering::Release);
                    } else if budget.expired(total_updates) {
                        ctrl.timed_out.store(true, Ordering::Release);
                        ctrl.done.store(true, Ordering::Release);
                    } else {
                        ctrl.max_diff.store(0.0);
                        ctrl.round.store(r + 1, Ordering::Release);
                    }
                }
            }
            c
        });

        // Copy the final buffer into the caller's state.
        let parity = ctrl.result_parity.load(Ordering::Acquire) as usize;
        msgs.restore(&bufs[parity].snapshot());

        Ok(EngineStats {
            converged: !ctrl.timed_out.load(Ordering::Acquire),
            wall_secs: timer.elapsed_secs(),
            metrics: MetricsReport::aggregate(&per_thread),
            final_max_priority: ctrl.max_diff.load(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::{all_marginals, exact_marginals, max_marginal_diff};
    use crate::configio::{AlgorithmSpec, ModelSpec};
    use crate::model::builders;

    fn run_sync(spec: ModelSpec, threads: usize, seed: u64) -> (Mrf, Messages, EngineStats) {
        let mrf = builders::build(&spec, seed);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::Synchronous)
            .with_threads(threads)
            .with_seed(seed);
        let stats = Synchronous.run(&mrf, &msgs, &cfg).unwrap();
        (mrf, msgs, stats)
    }

    #[test]
    fn tree_converges_in_height_rounds() {
        // Information travels one hop per round: #rounds ≈ height + 1.
        let (_, _, stats) = run_sync(ModelSpec::Tree { n: 127 }, 1, 1); // height 6
        assert!(stats.converged);
        let rounds = stats.metrics.total.rounds;
        assert!((6..=9).contains(&rounds), "rounds={rounds}");
        // Every round updates every message: updates = rounds × 252.
        assert_eq!(stats.metrics.total.updates, rounds * 252);
    }

    #[test]
    fn multithreaded_matches_single_thread() {
        let (m1, s1, st1) = run_sync(ModelSpec::Ising { n: 5 }, 1, 3);
        let (m2, s2, st2) = run_sync(ModelSpec::Ising { n: 5 }, 4, 3);
        assert!(st1.converged && st2.converged);
        assert_eq!(st1.metrics.total.rounds, st2.metrics.total.rounds);
        let a = all_marginals(&m1, &s1);
        let b = all_marginals(&m2, &s2);
        // Bitwise-identical schedules → identical marginals.
        assert!(max_marginal_diff(&a, &b) < 1e-12);
    }

    #[test]
    fn matches_oracle_on_small_grid() {
        let (mrf, msgs, stats) = run_sync(ModelSpec::Ising { n: 3 }, 2, 5);
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);
        let exact = exact_marginals(&mrf, 1 << 20).unwrap();
        assert!(max_marginal_diff(&bp, &exact) < 0.05);
    }

    #[test]
    fn ldpc_decodes_synchronously() {
        let inst = builders::ldpc::build(240, 0.04, 2);
        let msgs = Messages::uniform(&inst.mrf);
        // Tighter epsilon than the paper's 1e-2: on tiny codes the loose
        // threshold can stop before marginal flips fully resolve.
        let cfg = RunConfig::new(
            ModelSpec::Ldpc { n: 240, flip_prob: 0.04 },
            AlgorithmSpec::Synchronous,
        )
        .with_threads(2)
        .with_epsilon(1e-4);
        let stats = Synchronous.run(&inst.mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bits = crate::bp::decode_bits(&inst.mrf, &msgs, inst.num_vars);
        assert_eq!(bits, inst.sent);
    }

    #[test]
    fn budget_cuts_rounds() {
        let spec = ModelSpec::Ising { n: 6 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::Synchronous).with_max_updates(1);
        let stats = Synchronous.run(&mrf, &msgs, &cfg).unwrap();
        assert!(!stats.converged);
        assert_eq!(stats.metrics.total.rounds, 1);
    }
}
