//! BP scheduling engines — one per algorithm in the paper's §5.1 roster,
//! plus the Appendix-A optimal tree schedule and the PJRT-batched
//! extension.
//!
//! Every queue-driven engine is a thin [`crate::exec::TaskPolicy`] run on
//! the shared [`crate::exec::WorkerPool`] runtime; the scheduler is a
//! [`crate::sched::SchedChoice`] parameter of the pool. Round-based
//! engines (synchronous, bucket, random synch) and the sequential
//! baseline have no queue-driven worker loop and stay standalone.
//!
//! | Engine | `TaskPolicy` | Scheduler | Task | Paper label |
//! |---|---|---|---|---|
//! | [`sequential::SequentialResidual`] | — (sequential) | seq. heap | message | Residual (baseline) |
//! | [`synchronous::Synchronous`] | — (rounds) | none | all messages | Synch |
//! | [`residual_family::ResidualEngine`] | `ResidualPolicy` | `Exact` | message | Coarse-Grained |
//! | [`residual_family::ResidualEngine`] | `ResidualPolicy` | `Relaxed` | message | Relaxed Residual |
//! | [`residual_family::ResidualEngine`] | `ResidualPolicy` (decay) | `Relaxed` | message | Weight-Decay |
//! | [`no_lookahead::NoLookahead`] | `ScorePolicy` | `Relaxed` | message | Priority |
//! | [`splash::SplashEngine`] | `SplashPolicy` | `Exact`/`Relaxed`/`Random` | node splash | S / RSS / RS |
//! | [`bucket::Bucket`] | — (rounds) | rounds | top-0.1·V nodes | Bucket |
//! | [`random_synch::RandomSynch`] | — (rounds) | rounds | random subset | Random Synch |
//! | [`optimal_tree::OptimalTree`] | `OptimalTreePolicy` | `Exact`/`Relaxed` | message | Appendix A |
//! | [`batched::RelaxedResidualBatched`] | `BatchedPolicy` | `Relaxed` (batch drain) | message batch | (extension) |

pub mod batched;
pub mod bucket;
pub mod no_lookahead;
pub mod optimal_tree;
pub mod random_synch;
pub mod residual_family;
pub mod sequential;
pub mod splash;
pub mod synchronous;

use crate::bp::Messages;
use crate::configio::{AlgorithmSpec, RunConfig};
use crate::coordinator::MetricsReport;
use crate::exec::RunObserver;
use crate::model::{EvidenceDelta, Mrf};
use anyhow::Result;

/// Outcome of one engine run. Message state is left in `msgs` (owned by the
/// caller) for marginal extraction.
#[derive(Debug, Clone)]
pub struct EngineStats {
    /// True if the convergence criterion was met within budget.
    pub converged: bool,
    /// Wall-clock seconds spent inside the engine.
    pub wall_secs: f64,
    /// Aggregated counters.
    pub metrics: MetricsReport,
    /// Max task priority at exit (for residual-family engines ≈ max
    /// residual). Engines that verify convergence guarantee this is below
    /// `RunConfig::epsilon` on converged runs.
    pub final_max_priority: f64,
}

/// A BP scheduling engine: runs to convergence (or budget) on shared
/// message state.
pub trait Engine: Sync {
    /// Run to convergence or budget exhaustion, mutating `msgs` in place.
    fn run(&self, mrf: &Mrf, msgs: &Messages, cfg: &RunConfig) -> Result<EngineStats>;

    /// Like [`Engine::run`], additionally feeding `observer` periodic
    /// convergence samples (see [`RunObserver`]). Engines built on the
    /// [`crate::exec::WorkerPool`] runtime support this natively; the
    /// default implementation ignores the observer, so round-based engines
    /// still run — their traces just collapse to whatever the caller
    /// records from the final stats.
    fn run_observed(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        cfg: &RunConfig,
        observer: Option<&dyn RunObserver>,
    ) -> Result<EngineStats> {
        let _ = observer;
        self.run(mrf, msgs, cfg)
    }

    /// Warm-start re-convergence after an evidence delta: `mrf` already
    /// carries the perturbed priors (see
    /// [`EvidenceDelta::apply`](crate::model::EvidenceDelta::apply)) and
    /// `msgs` is the resident message state of a previous converged run —
    /// NOT `uniform_like`. `delta` names the perturbed nodes so the engine
    /// can seed only the affected frontier (the out-edges of those nodes,
    /// re-priced against the stored cells) and report its size as
    /// `tasks_touched`.
    ///
    /// The default implementation is warm-*correct* but not incremental:
    /// it re-runs the engine's full seed against the resident state, which
    /// reaches the same fixed point (residual seeding only changes *work*,
    /// never results — the verify sweep re-derives every priority from
    /// ground truth regardless of what was seeded). Engines with a
    /// delta-aware seeder override this; the analytic optimal-tree
    /// schedule keeps the default, since its completion criterion counts a
    /// fixed per-edge schedule that has no incremental form.
    fn resume(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        cfg: &RunConfig,
        delta: &EvidenceDelta,
        observer: Option<&dyn RunObserver>,
    ) -> Result<EngineStats> {
        let _ = delta;
        self.run_observed(mrf, msgs, cfg, observer)
    }

    /// Display name for reports.
    fn name(&self) -> String;
}

/// Instantiate the engine described by `cfg.algorithm`.
pub fn build_engine(spec: &AlgorithmSpec) -> Box<dyn Engine> {
    use AlgorithmSpec::*;
    match spec {
        SequentialResidual => Box::new(sequential::SequentialResidual),
        Synchronous => Box::new(synchronous::Synchronous),
        CoarseGrained => Box::new(residual_family::ResidualEngine::coarse_grained()),
        RelaxedResidual => Box::new(residual_family::ResidualEngine::relaxed()),
        WeightDecay => Box::new(residual_family::ResidualEngine::weight_decay()),
        Priority => Box::new(no_lookahead::NoLookahead),
        Splash { h } => Box::new(splash::SplashEngine::exact(*h, false)),
        SmartSplash { h } => Box::new(splash::SplashEngine::exact(*h, true)),
        RelaxedSmartSplash { h } => Box::new(splash::SplashEngine::relaxed(*h, true)),
        RandomSplash { h } => Box::new(splash::SplashEngine::random(*h, false)),
        Bucket => Box::new(bucket::Bucket::default()),
        RandomSynchronous { low_p } => Box::new(random_synch::RandomSynch { low_p: *low_p }),
        RelaxedResidualBatched { batch } => {
            Box::new(batched::RelaxedResidualBatched { batch: *batch })
        }
        OptimalTree => Box::new(optimal_tree::OptimalTree { relaxed: false }),
        RelaxedOptimalTree => Box::new(optimal_tree::OptimalTree { relaxed: true }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_all_engines() {
        let specs = [
            AlgorithmSpec::SequentialResidual,
            AlgorithmSpec::Synchronous,
            AlgorithmSpec::CoarseGrained,
            AlgorithmSpec::RelaxedResidual,
            AlgorithmSpec::WeightDecay,
            AlgorithmSpec::Priority,
            AlgorithmSpec::Splash { h: 2 },
            AlgorithmSpec::SmartSplash { h: 2 },
            AlgorithmSpec::RelaxedSmartSplash { h: 2 },
            AlgorithmSpec::RandomSplash { h: 2 },
            AlgorithmSpec::Bucket,
            AlgorithmSpec::RandomSynchronous { low_p: 0.4 },
            AlgorithmSpec::OptimalTree,
            AlgorithmSpec::RelaxedOptimalTree,
        ];
        for s in &specs {
            let e = build_engine(s);
            assert!(!e.name().is_empty());
        }
    }
}
