//! Residual BP *without lookahead* (Sutton–McCallum 2007) — the paper's
//! "Priority" algorithm — on the relaxed Multiqueue.
//!
//! Instead of precomputing `μ'` for every message (one extra message
//! computation per refresh), each message `e = (i→j)` carries a cheap
//! *score*: the accumulated L2 change of the other messages arriving at `i`
//! since `e` was last updated. The score upper-bound-approximates the true
//! residual; executing `e` computes the update once, commits it, and resets
//! the score.
//!
//! Priority maintenance is O(1) additions instead of O(deg) message
//! recomputations, trading scheduling precision for cheaper updates. The
//! worker loop itself is the shared [`WorkerPool`] runtime; this file only
//! supplies the [`ScorePolicy`].

use super::{Engine, EngineStats};
use crate::bp::{compute_message_with, msg_buf, Kernel, Messages, MsgBuf, MsgScratch, MsgSource};
use crate::configio::RunConfig;
use crate::exec::{ExecCtx, TaskPolicy, WorkerPool};
use crate::model::{EvidenceDelta, Mrf};
use crate::sched::SchedChoice;
use crate::util::AtomicF64;
use anyhow::Result;

/// The paper's "Priority" algorithm: residual BP without lookahead.
pub struct NoLookahead;

impl Engine for NoLookahead {
    fn name(&self) -> String {
        "priority".into()
    }

    fn run(&self, mrf: &Mrf, msgs: &Messages, cfg: &RunConfig) -> Result<EngineStats> {
        self.run_observed(mrf, msgs, cfg, None)
    }

    fn run_observed(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        cfg: &RunConfig,
        observer: Option<&dyn crate::exec::RunObserver>,
    ) -> Result<EngineStats> {
        let policy = ScorePolicy::new(mrf, msgs, cfg);
        Ok(WorkerPool::from_config(cfg, SchedChoice::Relaxed)
            .with_partition(crate::model::partition::for_messages(mrf, cfg))
            .run_observed(&policy, observer))
    }

    fn resume(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        cfg: &RunConfig,
        delta: &EvidenceDelta,
        observer: Option<&dyn crate::exec::RunObserver>,
    ) -> Result<EngineStats> {
        let policy = ScorePolicy::new_delta(mrf, msgs, cfg, delta);
        Ok(WorkerPool::from_config(cfg, SchedChoice::Relaxed)
            .with_partition(crate::model::partition::for_messages(mrf, cfg))
            .run_observed(&policy, observer))
    }
}

/// Message buffers reused across updates by one worker.
pub(crate) struct ScoreScratch {
    new: MsgBuf,
    /// Gather buffers for [`compute_message_with`] (no per-update
    /// MAX_DOMAIN-wide zeroing on wide-domain models).
    gather: MsgScratch,
}

/// Message-task policy with accumulated-change scores instead of true
/// residuals.
pub(crate) struct ScorePolicy<'a> {
    mrf: &'a Mrf,
    msgs: &'a Messages,
    /// Per-edge accumulated-change scores.
    scores: Vec<AtomicF64>,
    eps: f64,
    /// Data-path kernel (`RunConfig::kernel`).
    kernel: Kernel,
    /// Delta warm start: bootstrap scores only for the out-edges of these
    /// (perturbed) nodes. `None` = scratch run, full bootstrap sweep.
    seed_nodes: Option<Vec<u32>>,
}

impl<'a> ScorePolicy<'a> {
    pub(crate) fn new(mrf: &'a Mrf, msgs: &'a Messages, cfg: &RunConfig) -> Self {
        let mut scores = Vec::with_capacity(mrf.num_messages());
        scores.resize_with(mrf.num_messages(), AtomicF64::default);
        ScorePolicy { mrf, msgs, scores, eps: cfg.epsilon, kernel: cfg.kernel, seed_nodes: None }
    }

    /// Warm-start policy over a resident `msgs` state: scores start at 0
    /// everywhere (the resident state is a fixed point away from the
    /// delta) and only the perturbed nodes' out-edges get the one-time
    /// true-residual bootstrap.
    pub(crate) fn new_delta(
        mrf: &'a Mrf,
        msgs: &'a Messages,
        cfg: &RunConfig,
        delta: &EvidenceDelta,
    ) -> Self {
        let mut p = Self::new(mrf, msgs, cfg);
        p.seed_nodes = Some(delta.nodes().collect());
        p
    }
}

impl TaskPolicy for ScorePolicy<'_> {
    type Scratch = ScoreScratch;

    fn num_tasks(&self) -> usize {
        self.mrf.num_messages()
    }

    fn make_scratch(&self) -> Self::Scratch {
        ScoreScratch { new: msg_buf(), gather: MsgScratch::new() }
    }

    fn seed(&self, ctx: &mut ExecCtx<'_>) {
        // Initial scores are the true residuals (one-time lookahead pass;
        // Sutton–McCallum likewise bootstrap with a sweep). The residual
        // comes out of the kernel (`residual_l2_against`) — no second
        // message read just to price the edge.
        let mut buf = msg_buf();
        let mut gather = MsgScratch::new();
        let mut price = |e: u32| {
            let len =
                compute_message_with(self.mrf, self.msgs, e, &mut buf, &mut gather, self.kernel);
            let r = self.msgs.residual_l2_against(self.mrf, e, &buf[..len], self.kernel);
            self.scores[e as usize].store(r);
            r
        };
        match &self.seed_nodes {
            None => {
                for e in 0..self.mrf.num_messages() as u32 {
                    let r = price(e);
                    ctx.activate(e, r);
                }
            }
            Some(nodes) => {
                // Delta warm start: bootstrap only the perturbed frontier,
                // injected as one shard-grouped batch. (At seed time no
                // entries are outstanding, so the batched requeue's epoch
                // bump cannot strand a valid ticket.)
                let mut batch = Vec::new();
                for &i in nodes {
                    for s in self.mrf.graph.slots(i as usize) {
                        let e = self.mrf.graph.adj_out[s];
                        batch.push((e, price(e)));
                    }
                }
                ctx.counters.tasks_touched += batch.len() as u64;
                ctx.requeue_batch(&batch);
            }
        }
    }

    fn process(&self, tasks: &[u32], ctx: &mut ExecCtx<'_>, scratch: &mut ScoreScratch) -> u64 {
        for &e in tasks {
            // Compute the update now (no lookahead cache).
            let len = compute_message_with(
                self.mrf,
                self.msgs,
                e,
                &mut scratch.new,
                &mut scratch.gather,
                self.kernel,
            );
            // Fused store + in-kernel residual: one pass over the live
            // cells prices the update while committing it. (The scalar
            // kernel's value is bit-for-bit the historical read-current /
            // residual_l2 / write triple.)
            let r = self
                .msgs
                .write_msg_residual(self.mrf, e, &scratch.new[..len], self.kernel);
            self.scores[e as usize].store(0.0);
            ctx.counters.updates += 1;
            if r >= self.eps {
                ctx.counters.useful_updates += 1;
            } else {
                ctx.counters.wasted_pops += 1;
            }
            // Bump scores of the affected out-edges of dst.
            if r > 0.0 {
                let j = self.mrf.graph.edge_dst[e as usize] as usize;
                let rev = self.mrf.graph.reverse(e);
                for s in self.mrf.graph.slots(j) {
                    let k = self.mrf.graph.adj_out[s];
                    if k == rev {
                        continue;
                    }
                    // `activate`, not `requeue`: scores only grow until the
                    // next execution, so an existing entry stays a valid
                    // claim ticket — invalidating it on a sub-threshold
                    // change would strand the task until the verify sweep.
                    let prev = self.scores[k as usize].fetch_add(r);
                    ctx.activate(k, prev + r);
                }
            }
        }
        tasks.len() as u64
    }

    fn verify_sweep(&self, ctx: &mut ExecCtx<'_>) -> bool {
        // Verify against TRUE residuals: the score is only an approximation
        // and can reach 0 while the actual residual is not.
        let mut found = false;
        let mut nb = msg_buf();
        let mut gather = MsgScratch::new();
        for e in 0..self.mrf.num_messages() as u32 {
            let len =
                compute_message_with(self.mrf, self.msgs, e, &mut nb, &mut gather, self.kernel);
            let r = self.msgs.residual_l2_against(self.mrf, e, &nb[..len], self.kernel);
            // Overwrite unconditionally: a lost insert race can leave a
            // stale accumulated score above ε whose true residual is below;
            // syncing to ground truth keeps `final_priority` honest.
            self.scores[e as usize].store(r);
            if ctx.activate(e, r) {
                found = true;
            }
        }
        !found
    }

    fn arena_bytes(&self) -> (u64, u64) {
        // No lookahead cache: the live arenas are the whole footprint.
        let (l, p) = self.msgs.arena_bytes();
        (l as u64, p as u64)
    }

    fn final_priority(&self) -> f64 {
        self.scores.iter().map(|s| s.load()).fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::{all_marginals, exact_marginals, max_marginal_diff};
    use crate::configio::{AlgorithmSpec, ModelSpec};
    use crate::model::builders;

    #[test]
    fn tree_converges_exactly() {
        let spec = ModelSpec::Tree { n: 63 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::Priority).with_threads(2);
        let stats = NoLookahead.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);
        for m in bp {
            assert!((m[0] - 0.1).abs() < 1e-4, "{m:?}");
        }
    }

    #[test]
    fn ising_matches_oracle_approximately() {
        let spec = ModelSpec::Ising { n: 3 };
        let mrf = builders::build(&spec, 4);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::Priority);
        let stats = NoLookahead.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);
        let exact = exact_marginals(&mrf, 1 << 20).unwrap();
        assert!(max_marginal_diff(&bp, &exact) < 0.05);
    }

    #[test]
    fn score_approximation_needs_more_updates_than_residual() {
        // The paper's Table 6: Priority performs more updates than Relaxed
        // Residual (scores over-approximate). Check the direction holds.
        let spec = ModelSpec::Ising { n: 8 };
        let mrf = builders::build(&spec, 9);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::Priority).with_seed(9);
        let pri = NoLookahead.run(&mrf, &msgs, &cfg).unwrap();

        let mrf2 = builders::build(&spec, 9);
        let msgs2 = Messages::uniform(&mrf2);
        let cfg2 = RunConfig::new(spec, AlgorithmSpec::SequentialResidual).with_seed(9);
        let seq = super::super::sequential::SequentialResidual
            .run(&mrf2, &msgs2, &cfg2)
            .unwrap();

        assert!(pri.converged && seq.converged);
        assert!(
            pri.metrics.total.updates as f64 >= 0.9 * seq.metrics.total.updates as f64,
            "priority {} vs residual {}",
            pri.metrics.total.updates,
            seq.metrics.total.updates
        );
    }
}
