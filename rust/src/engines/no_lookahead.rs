//! Residual BP *without lookahead* (Sutton–McCallum 2007) — the paper's
//! "Priority" algorithm — on the relaxed Multiqueue.
//!
//! Instead of precomputing `μ'` for every message (one extra message
//! computation per refresh), each message `e = (i→j)` carries a cheap
//! *score*: the accumulated L2 change of the other messages arriving at `i`
//! since `e` was last updated. The score upper-bound-approximates the true
//! residual; executing `e` computes the update once, commits it, and resets
//! the score.
//!
//! Priority maintenance is O(1) additions instead of O(deg) message
//! recomputations, trading scheduling precision for cheaper updates.

use super::{Engine, EngineStats};
use crate::bp::{compute_message, msg_buf, residual_l2, Messages, MsgSource};
use crate::configio::RunConfig;
use crate::coordinator::{run_workers, Budget, Counters, MetricsReport, Termination};
use crate::model::Mrf;
use crate::sched::{Entry, Multiqueue, Scheduler, TaskStates};
use crate::util::{AtomicF64, Timer, Xoshiro256};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, Ordering};

pub struct NoLookahead;

impl Engine for NoLookahead {
    fn name(&self) -> String {
        "priority".into()
    }

    fn run(&self, mrf: &Mrf, msgs: &Messages, cfg: &RunConfig) -> Result<EngineStats> {
        let timer = Timer::start();
        let budget = Budget::new(cfg.time_limit_secs, cfg.max_updates);
        let eps = cfg.epsilon;

        let sched = Multiqueue::for_threads(cfg.threads, cfg.queues_per_thread);
        let ts = TaskStates::new(mrf.num_messages());
        let term = Termination::new();
        let timed_out = AtomicBool::new(false);

        // Per-edge accumulated-change scores.
        let mut scores = Vec::with_capacity(mrf.num_messages());
        scores.resize_with(mrf.num_messages(), AtomicF64::default);

        // Seed: initial scores are the true residuals (one-time lookahead
        // pass; Sutton–McCallum likewise bootstrap with a sweep).
        {
            let mut rng = Xoshiro256::stream(cfg.seed, 0xACE);
            let mut buf = msg_buf();
            let mut cur = msg_buf();
            for e in 0..mrf.num_messages() as u32 {
                let len = compute_message(mrf, msgs, e, &mut buf);
                msgs.read_msg(mrf, e, &mut cur);
                let r = residual_l2(&buf[..len], &cur[..len]);
                scores[e as usize].store(r);
                if r >= eps {
                    term.before_insert();
                    sched.insert(Entry { prio: r, task: e, epoch: ts.epoch(e) }, &mut rng);
                }
            }
        }

        let per_thread = run_workers(cfg.threads, |tid| {
            let mut rng = Xoshiro256::stream(cfg.seed, 2000 + tid as u64);
            let mut c = Counters::default();
            let mut new = msg_buf();
            let mut cur = msg_buf();
            let mut since_flush: u64 = 0;

            while !term.is_done() {
                term.enter();
                match sched.pop(&mut rng) {
                    Some(ent) => {
                        term.after_pop();
                        c.pops += 1;
                        if ent.epoch != ts.epoch(ent.task) {
                            c.stale_pops += 1;
                            term.exit();
                            continue;
                        }
                        if !ts.try_claim(ent.task, ent.epoch) {
                            c.claim_failures += 1;
                            term.exit();
                            continue;
                        }
                        let e = ent.task;
                        // Compute the update now (no lookahead cache).
                        let len = compute_message(mrf, msgs, e, &mut new);
                        msgs.read_msg(mrf, e, &mut cur);
                        let r = residual_l2(&new[..len], &cur[..len]);
                        msgs.write_msg(mrf, e, &new[..len]);
                        scores[e as usize].store(0.0);
                        c.updates += 1;
                        since_flush += 1;
                        if r >= eps {
                            c.useful_updates += 1;
                        } else {
                            c.wasted_pops += 1;
                        }
                        // Bump scores of the affected out-edges of dst.
                        if r > 0.0 {
                            let j = mrf.graph.edge_dst[e as usize] as usize;
                            let rev = mrf.graph.reverse(e);
                            for s in mrf.graph.slots(j) {
                                let k = mrf.graph.adj_out[s];
                                if k == rev {
                                    continue;
                                }
                                let prev = scores[k as usize].fetch_add(r);
                                let p = prev + r;
                                if p >= eps {
                                    let epoch = ts.bump(k);
                                    term.before_insert();
                                    sched.insert(Entry { prio: p, task: k, epoch }, &mut rng);
                                    c.inserts += 1;
                                }
                            }
                        }
                        ts.release(e);
                        term.exit();

                        if since_flush >= 256 {
                            let g = term
                                .global_updates
                                .fetch_add(since_flush, Ordering::Relaxed)
                                + since_flush;
                            since_flush = 0;
                            if budget.expired(g) {
                                timed_out.store(true, Ordering::Release);
                                term.set_done();
                            }
                        }
                    }
                    None => {
                        term.exit();
                        if term.quiescent() {
                            term.try_verify(|| {
                                // Verify against TRUE residuals: the score
                                // is only an approximation and can reach 0
                                // while the actual residual is not.
                                let mut found = false;
                                let mut nb = msg_buf();
                                let mut cb = msg_buf();
                                for e in 0..mrf.num_messages() as u32 {
                                    let len = compute_message(mrf, msgs, e, &mut nb);
                                    msgs.read_msg(mrf, e, &mut cb);
                                    let r = residual_l2(&nb[..len], &cb[..len]);
                                    if r >= eps {
                                        scores[e as usize].store(r);
                                        let epoch = ts.bump(e);
                                        term.before_insert();
                                        sched.insert(
                                            Entry { prio: r, task: e, epoch },
                                            &mut rng,
                                        );
                                        found = true;
                                    }
                                }
                                !found
                            });
                        } else {
                            std::thread::yield_now();
                            if budget.expired(term.global_updates.load(Ordering::Relaxed)) {
                                timed_out.store(true, Ordering::Release);
                                term.set_done();
                            }
                        }
                    }
                }
            }
            c
        });

        let final_max = scores.iter().map(|s| s.load()).fold(0.0, f64::max);
        Ok(EngineStats {
            converged: !timed_out.load(Ordering::Acquire),
            wall_secs: timer.elapsed_secs(),
            metrics: MetricsReport::aggregate(&per_thread),
            final_max_priority: final_max,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::{all_marginals, exact_marginals, max_marginal_diff};
    use crate::configio::{AlgorithmSpec, ModelSpec};
    use crate::model::builders;

    #[test]
    fn tree_converges_exactly() {
        let spec = ModelSpec::Tree { n: 63 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::Priority).with_threads(2);
        let stats = NoLookahead.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);
        for m in bp {
            assert!((m[0] - 0.1).abs() < 1e-4, "{m:?}");
        }
    }

    #[test]
    fn ising_matches_oracle_approximately() {
        let spec = ModelSpec::Ising { n: 3 };
        let mrf = builders::build(&spec, 4);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::Priority);
        let stats = NoLookahead.run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);
        let exact = exact_marginals(&mrf, 1 << 20).unwrap();
        assert!(max_marginal_diff(&bp, &exact) < 0.05);
    }

    #[test]
    fn score_approximation_needs_more_updates_than_residual() {
        // The paper's Table 6: Priority performs more updates than Relaxed
        // Residual (scores over-approximate). Check the direction holds.
        let spec = ModelSpec::Ising { n: 8 };
        let mrf = builders::build(&spec, 9);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::Priority).with_seed(9);
        let pri = NoLookahead.run(&mrf, &msgs, &cfg).unwrap();

        let mrf2 = builders::build(&spec, 9);
        let msgs2 = Messages::uniform(&mrf2);
        let cfg2 = RunConfig::new(spec, AlgorithmSpec::SequentialResidual).with_seed(9);
        let seq = super::super::sequential::SequentialResidual
            .run(&mrf2, &msgs2, &cfg2)
            .unwrap();

        assert!(pri.converged && seq.converged);
        assert!(
            pri.metrics.total.updates as f64 >= 0.9 * seq.metrics.total.updates as f64,
            "priority {} vs residual {}",
            pri.metrics.total.updates,
            seq.metrics.total.updates
        );
    }
}
