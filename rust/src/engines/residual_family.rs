//! The concurrent residual-BP family: Coarse-Grained (exact PQ), Relaxed
//! Residual (Multiqueue), and Weight-Decay (Multiqueue with `res/m`
//! priorities) — §3.2/§3.3 of the paper.
//!
//! All three are one [`ResidualPolicy`] on the [`WorkerPool`]; they differ
//! only in the [`SchedChoice`] and in the priority function:
//!
//! - residual: `prio(e) = res(e) = ‖μ'_e − μ_e‖₂`;
//! - weight-decay (Knoll et al. 2015): `prio(e) = res(e) / m(e)` where
//!   `m(e)` counts how many times `e` has been committed — de-prioritizing
//!   messages stuck in large-residual cycles.
//!
//! Processing follows §3.3: commit the precomputed update, then refresh +
//! requeue the affected out-edges — through the node-centric fused kernel
//! (`Lookahead::refresh_node`, one O(deg) pass + one batched insert) when
//! `RunConfig::fused` is on (the default), or edge-by-edge when off. The
//! pop → validate epoch → claim protocol and the quiescence + verify
//! termination live in the runtime.

use super::{Engine, EngineStats};
use crate::bp::{Lookahead, Messages, MsgScratch, NodeScratch};
use crate::configio::RunConfig;
use crate::exec::{ExecCtx, TaskPolicy, WorkerPool};
use crate::model::{EvidenceDelta, Mrf};
use crate::sched::SchedChoice;
use anyhow::Result;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    CoarseGrained,
    Relaxed,
    WeightDecay,
}

/// Coarse-Grained / Relaxed Residual / Weight-Decay, selected by constructor.
pub struct ResidualEngine {
    kind: Kind,
}

impl ResidualEngine {
    /// Exact residual BP on a single locked PQ (paper's "Coarse-Grained").
    pub fn coarse_grained() -> Self {
        Self { kind: Kind::CoarseGrained }
    }

    /// Relaxed residual BP on the Multiqueue (the headline algorithm).
    pub fn relaxed() -> Self {
        Self { kind: Kind::Relaxed }
    }

    /// Weight-decay priorities on the Multiqueue.
    pub fn weight_decay() -> Self {
        Self { kind: Kind::WeightDecay }
    }
}

impl ResidualEngine {
    fn choice(&self) -> SchedChoice {
        match self.kind {
            Kind::CoarseGrained => SchedChoice::Exact,
            _ => SchedChoice::Relaxed,
        }
    }

    fn run_policy(
        &self,
        mrf: &Mrf,
        cfg: &RunConfig,
        policy: &ResidualPolicy<'_>,
        observer: Option<&dyn crate::exec::RunObserver>,
    ) -> EngineStats {
        WorkerPool::from_config(cfg, self.choice())
            .with_partition(crate::model::partition::for_messages(mrf, cfg))
            .run_observed(policy, observer)
    }
}

impl Engine for ResidualEngine {
    fn name(&self) -> String {
        match self.kind {
            Kind::CoarseGrained => "coarse_grained".into(),
            Kind::Relaxed => "relaxed_residual".into(),
            Kind::WeightDecay => "weight_decay".into(),
        }
    }

    fn run(&self, mrf: &Mrf, msgs: &Messages, cfg: &RunConfig) -> Result<EngineStats> {
        self.run_observed(mrf, msgs, cfg, None)
    }

    fn run_observed(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        cfg: &RunConfig,
        observer: Option<&dyn crate::exec::RunObserver>,
    ) -> Result<EngineStats> {
        let policy = ResidualPolicy::new(mrf, msgs, cfg, self.kind == Kind::WeightDecay);
        Ok(self.run_policy(mrf, cfg, &policy, observer))
    }

    fn resume(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        cfg: &RunConfig,
        delta: &EvidenceDelta,
        observer: Option<&dyn crate::exec::RunObserver>,
    ) -> Result<EngineStats> {
        let policy =
            ResidualPolicy::new_delta(mrf, msgs, cfg, self.kind == Kind::WeightDecay, delta);
        Ok(self.run_policy(mrf, cfg, &policy, observer))
    }
}

/// Message-task policy with residual (or weight-decayed residual)
/// priorities and one-step lookahead. Shared by Coarse-Grained, Relaxed
/// Residual, and Weight-Decay.
pub(crate) struct ResidualPolicy<'a> {
    mrf: &'a Mrf,
    msgs: &'a Messages,
    la: Lookahead,
    /// Per-message commit counts (weight-decay only).
    counts: Option<Vec<AtomicU32>>,
    eps: f64,
    /// Use the node-centric fused refresh + batched requeue
    /// (`RunConfig::fused`); off forces the per-edge fan-out for A/B.
    fused: bool,
    /// Delta warm start: seed only the out-edges of these (perturbed)
    /// nodes instead of every message. `None` = scratch run, full seed.
    seed_nodes: Option<Vec<u32>>,
    /// Distributed-runtime hooks (rank-ownership filter, boundary
    /// publication, ingress, rank-level termination); `None` keeps every
    /// single-process path byte-identical.
    dist: Option<&'a dyn crate::net::DistDriver>,
    /// Ingress-activity epoch at which the last verify sweep came back
    /// clean (`u64::MAX` = never). While a distributed rank idles waiting
    /// for the termination token, nothing can change its residuals except
    /// a boundary arrival — so an unchanged epoch lets the verifier skip
    /// re-sweeping on every protocol attempt.
    clean_epoch: AtomicU64,
}

/// Per-worker buffers for the refresh paths: the fused kernel's
/// prefix/suffix scratch, the edge-wise gather buffers, and the
/// `(edge, residual)` requeue batch.
pub(crate) struct RefreshScratch {
    node: NodeScratch,
    gather: MsgScratch,
    batch: Vec<(u32, f64)>,
    /// Arrived boundary edges taken from the distributed inbox.
    inbox: Vec<u32>,
}

impl<'a> ResidualPolicy<'a> {
    pub(crate) fn new(
        mrf: &'a Mrf,
        msgs: &'a Messages,
        cfg: &RunConfig,
        weight_decay: bool,
    ) -> Self {
        let counts = weight_decay.then(|| {
            let mut v = Vec::with_capacity(mrf.num_messages());
            v.resize_with(mrf.num_messages(), || AtomicU32::new(0));
            v
        });
        let la = if cfg.fused {
            Lookahead::init_fused(mrf, msgs, cfg.kernel)
        } else {
            Lookahead::init(mrf, msgs, cfg.kernel)
        };
        ResidualPolicy {
            mrf,
            msgs,
            la,
            counts,
            eps: cfg.epsilon,
            fused: cfg.fused,
            seed_nodes: None,
            dist: None,
            clean_epoch: AtomicU64::new(u64::MAX),
        }
    }

    /// Distributed-rank policy: identical to [`ResidualPolicy::new`]
    /// (plain residual priorities) but every seed/requeue site filters to
    /// the rank's owned tasks, committed boundary values are published
    /// through `dist`, arrived mirror updates are drained into the local
    /// scheduler, and the pool's termination gate runs the rank-level
    /// protocol.
    pub(crate) fn new_dist(
        mrf: &'a Mrf,
        msgs: &'a Messages,
        cfg: &RunConfig,
        dist: &'a dyn crate::net::DistDriver,
    ) -> Self {
        let mut p = Self::new(mrf, msgs, cfg, false);
        p.dist = Some(dist);
        p
    }

    /// Warm-start policy over a resident `msgs` state: the lookahead cache
    /// is delta-primed (only the perturbed nodes' out-edges re-priced; see
    /// [`Lookahead::init_delta`]) and [`TaskPolicy::seed`] will inject only
    /// that frontier.
    pub(crate) fn new_delta(
        mrf: &'a Mrf,
        msgs: &'a Messages,
        cfg: &RunConfig,
        weight_decay: bool,
        delta: &EvidenceDelta,
    ) -> Self {
        let nodes: Vec<u32> = delta.nodes().collect();
        let counts = weight_decay.then(|| {
            let mut v = Vec::with_capacity(mrf.num_messages());
            v.resize_with(mrf.num_messages(), || AtomicU32::new(0));
            v
        });
        let la = if cfg.fused {
            Lookahead::init_delta_fused(mrf, msgs, cfg.kernel, &nodes)
        } else {
            Lookahead::init_delta(mrf, msgs, cfg.kernel, &nodes)
        };
        ResidualPolicy {
            mrf,
            msgs,
            la,
            counts,
            eps: cfg.epsilon,
            fused: cfg.fused,
            seed_nodes: Some(nodes),
            dist: None,
            clean_epoch: AtomicU64::new(u64::MAX),
        }
    }

    /// Priority of edge `e` given its residual (weight-decay divides by the
    /// execution count).
    #[inline]
    fn priority(&self, res: f64, e: u32) -> f64 {
        match &self.counts {
            None => res,
            Some(c) => res / (c[e as usize].load(Ordering::Relaxed).max(1) as f64),
        }
    }

    /// True when this process may schedule task `e` (always, outside a
    /// distributed run).
    #[inline]
    fn owned(&self, e: u32) -> bool {
        match self.dist {
            None => true,
            Some(d) => d.owns(e),
        }
    }
}

impl TaskPolicy for ResidualPolicy<'_> {
    type Scratch = RefreshScratch;

    fn num_tasks(&self) -> usize {
        self.mrf.num_messages()
    }

    fn make_scratch(&self) -> Self::Scratch {
        RefreshScratch {
            node: NodeScratch::new(),
            gather: MsgScratch::new(),
            batch: Vec::new(),
            inbox: Vec::new(),
        }
    }

    fn seed(&self, ctx: &mut ExecCtx<'_>) {
        match &self.seed_nodes {
            None => {
                for e in 0..self.mrf.num_messages() as u32 {
                    if !self.owned(e) {
                        continue;
                    }
                    ctx.requeue(e, self.priority(self.la.residual(e), e));
                }
            }
            Some(nodes) => {
                // Delta warm start: inject exactly the re-priced frontier
                // (out-edges of the perturbed nodes) through the batched
                // insert path, so with the locality axis on every entry
                // lands in its shard's queue group. Everything else keeps
                // residual 0 from the delta-primed cache; the verify sweep
                // is the safety net for anything the frontier misses.
                let mut batch = Vec::new();
                for &i in nodes {
                    for s in self.mrf.graph.slots(i as usize) {
                        let e = self.mrf.graph.adj_out[s];
                        if !self.owned(e) {
                            continue;
                        }
                        batch.push((e, self.priority(self.la.residual(e), e)));
                    }
                }
                ctx.counters.tasks_touched += batch.len() as u64;
                ctx.requeue_batch(&batch);
            }
        }
    }

    fn process(&self, tasks: &[u32], ctx: &mut ExecCtx<'_>, sc: &mut RefreshScratch) -> u64 {
        for &e in tasks {
            // Commit the precomputed update.
            let res = self.la.commit(self.mrf, self.msgs, e);
            ctx.counters.updates += 1;
            if res >= self.eps {
                ctx.counters.useful_updates += 1;
            } else {
                ctx.counters.wasted_pops += 1;
            }
            if let Some(counts) = &self.counts {
                counts[e as usize].fetch_add(1, Ordering::Relaxed);
            }
            if let Some(d) = self.dist {
                // Owned boundary edge: ship the value that actually
                // landed (damping included) to its remote consumers.
                d.publish(self.mrf, self.msgs, e);
            }
            if self.fused {
                // Fused refresh of dst's out-set (minus the unaffected
                // reverse edge): one O(deg) node pass, then one batched
                // scheduler insert for the whole affected set.
                let j = self.mrf.graph.edge_dst[e as usize];
                sc.batch.clear();
                self.la.refresh_node(
                    self.mrf,
                    self.msgs,
                    j,
                    Some(self.mrf.graph.reverse(e)),
                    &mut sc.node,
                    &mut sc.batch,
                );
                if self.dist.is_some() {
                    sc.batch.retain(|&(k, _)| self.owned(k));
                }
                ctx.counters.refreshes += sc.batch.len() as u64;
                if self.counts.is_some() {
                    for item in sc.batch.iter_mut() {
                        item.1 = self.priority(item.1, item.0);
                    }
                }
                ctx.requeue_batch(&sc.batch);
            } else {
                // Edge-wise fan-out: O(deg) full gathers = O(deg²) reads.
                for k in self.la.affected_edges(self.mrf, e) {
                    if !self.owned(k) {
                        continue;
                    }
                    let r = self.la.refresh(self.mrf, self.msgs, k, &mut sc.gather);
                    ctx.counters.refreshes += 1;
                    ctx.requeue(k, self.priority(r, k));
                }
            }
        }
        tasks.len() as u64
    }

    fn verify_sweep(&self, ctx: &mut ExecCtx<'_>) -> bool {
        // Distributed ranks idle-wait for the termination token under
        // quiescence, re-entering this sweep on every protocol attempt.
        // Between attempts only a boundary arrival (which bumps the
        // activity epoch) can change any local residual, so a clean sweep
        // stays valid while the epoch is unchanged. The epoch is read
        // *before* sweeping: an arrival mid-sweep invalidates the cache.
        let epoch = self.dist.map(|d| d.activity_epoch());
        if let Some(ep) = epoch {
            if self.clean_epoch.load(Ordering::Acquire) == ep {
                return true;
            }
        }
        // Full refresh of every owned edge repairs any residual lost to
        // benign write races. One refresh_node per node covers every
        // directed edge exactly once (each edge has one source node).
        let mut found = false;
        if self.fused {
            let mut sc = NodeScratch::new();
            let mut batch = Vec::new();
            for j in 0..self.mrf.num_nodes() as u32 {
                batch.clear();
                self.la.refresh_node(self.mrf, self.msgs, j, None, &mut sc, &mut batch);
                for &(e, r) in &batch {
                    if !self.owned(e) {
                        continue;
                    }
                    if ctx.requeue(e, self.priority(r, e)) {
                        found = true;
                    }
                }
            }
        } else {
            let mut gather = MsgScratch::new();
            for e in 0..self.mrf.num_messages() as u32 {
                if !self.owned(e) {
                    continue;
                }
                let r = self.la.refresh(self.mrf, self.msgs, e, &mut gather);
                if ctx.requeue(e, self.priority(r, e)) {
                    found = true;
                }
            }
        }
        if !found {
            if let Some(ep) = epoch {
                self.clean_epoch.store(ep, Ordering::Release);
            }
        }
        !found
    }

    fn drain_ingress(&self, ctx: &mut ExecCtx<'_>, sc: &mut RefreshScratch) -> u64 {
        let Some(d) = self.dist else { return 0 };
        sc.inbox.clear();
        d.take_inbox(&mut sc.inbox);
        if sc.inbox.is_empty() {
            return 0;
        }
        // A mirror cell changed: re-price the owned out-edges it feeds
        // (the remote update's fan-out crossed the rank boundary) and
        // requeue them shard-affine. The values themselves were already
        // applied by the reader thread.
        for idx in 0..sc.inbox.len() {
            let e = sc.inbox[idx];
            if self.fused {
                let j = self.mrf.graph.edge_dst[e as usize];
                sc.batch.clear();
                self.la.refresh_node(
                    self.mrf,
                    self.msgs,
                    j,
                    Some(self.mrf.graph.reverse(e)),
                    &mut sc.node,
                    &mut sc.batch,
                );
                sc.batch.retain(|&(k, _)| self.owned(k));
                ctx.counters.refreshes += sc.batch.len() as u64;
                ctx.requeue_batch(&sc.batch);
            } else {
                for k in self.la.affected_edges(self.mrf, e) {
                    if !self.owned(k) {
                        continue;
                    }
                    let r = self.la.refresh(self.mrf, self.msgs, k, &mut sc.gather);
                    ctx.counters.refreshes += 1;
                    ctx.requeue(k, self.priority(r, k));
                }
            }
        }
        sc.inbox.len() as u64
    }

    fn try_finish(&self) -> bool {
        match self.dist {
            None => true,
            Some(d) => d.try_finish(),
        }
    }

    fn arena_bytes(&self) -> (u64, u64) {
        let (live_l, live_p) = self.msgs.arena_bytes();
        let (la_l, la_p) = self.la.arena_bytes();
        ((live_l + la_l) as u64, (live_p + la_p) as u64)
    }

    fn final_priority(&self) -> f64 {
        // Max *priority*, not raw residual: under weight decay a converged
        // run can retain residuals above ε whose decayed priority is
        // below. Distributed ranks report owned tasks only — a mirror's
        // residual prices a task some other rank converged.
        (0..self.mrf.num_messages() as u32)
            .filter(|&e| self.owned(e))
            .map(|e| self.priority(self.la.residual(e), e))
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::{all_marginals, exact_marginals, max_marginal_diff};
    use crate::configio::{AlgorithmSpec, ModelSpec};
    use crate::model::builders;

    fn run_with(
        engine: &ResidualEngine,
        spec: ModelSpec,
        threads: usize,
        seed: u64,
    ) -> (Mrf, Messages, EngineStats) {
        let mrf = builders::build(&spec, seed);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::RelaxedResidual)
            .with_threads(threads)
            .with_seed(seed);
        let stats = engine.run(&mrf, &msgs, &cfg).unwrap();
        (mrf, msgs, stats)
    }

    #[test]
    fn relaxed_tree_converges_near_optimal() {
        let (_, _, stats) =
            run_with(&ResidualEngine::relaxed(), ModelSpec::Tree { n: 255 }, 1, 1);
        assert!(stats.converged);
        // Relaxation may waste a few updates but not blow up (Lemma 2).
        assert!(stats.metrics.total.updates >= 254);
        assert!(stats.metrics.total.updates < 2 * 254, "{}", stats.metrics.total.updates);
        assert!(stats.final_max_priority < 1e-5);
    }

    #[test]
    fn relaxed_matches_exact_marginals_on_tree() {
        let (mrf, msgs, stats) =
            run_with(&ResidualEngine::relaxed(), ModelSpec::Tree { n: 15 }, 2, 3);
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);
        let exact = exact_marginals(&mrf, 1 << 20).unwrap();
        assert!(max_marginal_diff(&bp, &exact) < 1e-4);
    }

    #[test]
    fn coarse_grained_converges_multithreaded() {
        let (mrf, msgs, stats) =
            run_with(&ResidualEngine::coarse_grained(), ModelSpec::Ising { n: 6 }, 4, 5);
        assert!(stats.converged, "max prio {}", stats.final_max_priority);
        let bp = all_marginals(&mrf, &msgs);
        for m in &bp {
            assert!((m.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn relaxed_ising_multithreaded_matches_sequential_marginals() {
        let spec = ModelSpec::Ising { n: 6 };
        let (mrf, msgs, stats) = run_with(&ResidualEngine::relaxed(), spec.clone(), 4, 7);
        assert!(stats.converged);
        let relaxed_marg = all_marginals(&mrf, &msgs);

        let mrf2 = builders::build(&spec, 7);
        let msgs2 = Messages::uniform(&mrf2);
        let cfg2 = RunConfig::new(spec, AlgorithmSpec::SequentialResidual).with_seed(7);
        let s2 = super::super::sequential::SequentialResidual.run(&mrf2, &msgs2, &cfg2).unwrap();
        assert!(s2.converged);
        let seq_marg = all_marginals(&mrf2, &msgs2);

        // Same fixed point (within convergence tolerance amplification).
        assert!(
            max_marginal_diff(&relaxed_marg, &seq_marg) < 1e-2,
            "diff = {}",
            max_marginal_diff(&relaxed_marg, &seq_marg)
        );
    }

    #[test]
    fn weight_decay_converges() {
        let (_, _, stats) =
            run_with(&ResidualEngine::weight_decay(), ModelSpec::Potts { n: 6, q: 3 }, 2, 9);
        assert!(stats.converged);
        assert!(stats.metrics.total.updates > 0);
    }

    #[test]
    fn ldpc_decodes_relaxed_multithreaded() {
        let inst = builders::ldpc::build(60, 0.05, 11);
        let msgs = Messages::uniform(&inst.mrf);
        let cfg = RunConfig::new(
            ModelSpec::Ldpc { n: 60, flip_prob: 0.05 },
            AlgorithmSpec::RelaxedResidual,
        )
        .with_threads(4)
        .with_seed(11);
        let stats = ResidualEngine::relaxed().run(&inst.mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bits = crate::bp::decode_bits(&inst.mrf, &msgs, inst.num_vars);
        assert_eq!(bits, inst.sent);
    }

    #[test]
    fn edgewise_and_fused_share_the_fixed_point() {
        let spec = ModelSpec::Ising { n: 5 };
        let mrf = builders::build(&spec, 13);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::RelaxedResidual)
            .with_threads(2)
            .with_seed(13)
            .with_fused(false);
        let s = ResidualEngine::relaxed().run(&mrf, &msgs, &cfg).unwrap();
        assert!(s.converged, "edgewise run converges");
        let edgewise = all_marginals(&mrf, &msgs);

        let mrf2 = builders::build(&spec, 13);
        let msgs2 = Messages::uniform(&mrf2);
        let cfg2 = RunConfig::new(spec, AlgorithmSpec::RelaxedResidual)
            .with_threads(2)
            .with_seed(13)
            .with_fused(true);
        let s2 = ResidualEngine::relaxed().run(&mrf2, &msgs2, &cfg2).unwrap();
        assert!(s2.converged, "fused run converges");
        let fused = all_marginals(&mrf2, &msgs2);
        assert!(
            max_marginal_diff(&edgewise, &fused) < 1e-2,
            "diff = {}",
            max_marginal_diff(&edgewise, &fused)
        );
        // The fused run's telemetry records its refresh fan-out and
        // batched inserts.
        assert!(s2.metrics.total.refreshes > 0);
        assert!(s2.metrics.total.insert_batches > 0);
        assert!(s.metrics.total.insert_batches == 0, "edgewise path never batches");
    }

    #[test]
    fn budget_timeout_reported() {
        let spec = ModelSpec::Ising { n: 12 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::RelaxedResidual)
            .with_threads(2)
            .with_max_updates(300);
        let stats = ResidualEngine::relaxed().run(&mrf, &msgs, &cfg).unwrap();
        assert!(!stats.converged);
    }

    #[test]
    fn update_overhead_vs_sequential_small() {
        // Table 3's phenomenon in miniature: relaxed performs only slightly
        // more updates than the sequential baseline.
        let spec = ModelSpec::Ising { n: 8 };
        let mrf = builders::build(&spec, 21);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::SequentialResidual).with_seed(21);
        let seq = super::super::sequential::SequentialResidual.run(&mrf, &msgs, &cfg).unwrap();

        let mrf2 = builders::build(&spec, 21);
        let msgs2 = Messages::uniform(&mrf2);
        let cfg2 = RunConfig::new(spec, AlgorithmSpec::RelaxedResidual).with_seed(21);
        let rel = ResidualEngine::relaxed().run(&mrf2, &msgs2, &cfg2).unwrap();

        assert!(seq.converged && rel.converged);
        let ratio = rel.metrics.total.updates as f64 / seq.metrics.total.updates as f64;
        assert!(ratio < 1.6, "single-thread relaxed overhead ratio {ratio}");
    }
}
