//! The concurrent residual-BP family: Coarse-Grained (exact PQ), Relaxed
//! Residual (Multiqueue), and Weight-Decay (Multiqueue with `res/m`
//! priorities) — §3.2/§3.3 of the paper.
//!
//! All three share one worker loop; they differ only in the scheduler
//! behind the [`Scheduler`] trait and in the priority function:
//!
//! - residual: `prio(e) = res(e) = ‖μ'_e − μ_e‖₂`;
//! - weight-decay (Knoll et al. 2015): `prio(e) = res(e) / m(e)` where
//!   `m(e)` counts how many times `e` has been committed — de-prioritizing
//!   messages stuck in large-residual cycles.
//!
//! The loop follows §3.3: pop → validate epoch → claim ("mark in-process")
//! → commit the precomputed update → refresh + requeue affected messages →
//! release. Termination uses the coordinator's quiescence + verify
//! protocol, which re-scans true residuals before declaring convergence.

use super::{Engine, EngineStats};
use crate::bp::{Lookahead, Messages};
use crate::configio::RunConfig;
use crate::coordinator::{run_workers, Budget, Counters, MetricsReport, Termination};
use crate::model::Mrf;
use crate::sched::{Entry, ExactQueue, Multiqueue, Scheduler, TaskStates};
use crate::util::{Timer, Xoshiro256};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    CoarseGrained,
    Relaxed,
    WeightDecay,
}

pub struct ResidualEngine {
    kind: Kind,
}

impl ResidualEngine {
    /// Exact residual BP on a single locked PQ (paper's "Coarse-Grained").
    pub fn coarse_grained() -> Self {
        Self { kind: Kind::CoarseGrained }
    }

    /// Relaxed residual BP on the Multiqueue (the headline algorithm).
    pub fn relaxed() -> Self {
        Self { kind: Kind::Relaxed }
    }

    /// Weight-decay priorities on the Multiqueue.
    pub fn weight_decay() -> Self {
        Self { kind: Kind::WeightDecay }
    }
}

impl Engine for ResidualEngine {
    fn name(&self) -> String {
        match self.kind {
            Kind::CoarseGrained => "coarse_grained".into(),
            Kind::Relaxed => "relaxed_residual".into(),
            Kind::WeightDecay => "weight_decay".into(),
        }
    }

    fn run(&self, mrf: &Mrf, msgs: &Messages, cfg: &RunConfig) -> Result<EngineStats> {
        let sched: Box<dyn Scheduler> = match self.kind {
            Kind::CoarseGrained => Box::new(ExactQueue::with_capacity(mrf.num_messages())),
            _ => Box::new(Multiqueue::for_threads(cfg.threads, cfg.queues_per_thread)),
        };
        let update_counts = match self.kind {
            Kind::WeightDecay => {
                let mut v = Vec::with_capacity(mrf.num_messages());
                v.resize_with(mrf.num_messages(), || AtomicU32::new(0));
                Some(v)
            }
            _ => None,
        };
        run_residual_loop(mrf, msgs, cfg, sched.as_ref(), update_counts.as_deref())
    }
}

/// Priority of edge `e` given its residual (weight-decay divides by the
/// execution count).
#[inline]
fn priority(res: f64, e: u32, counts: Option<&[AtomicU32]>) -> f64 {
    match counts {
        None => res,
        Some(c) => res / (c[e as usize].load(Ordering::Relaxed).max(1) as f64),
    }
}

/// The shared worker loop. Exposed to the batched engine as well.
pub(crate) fn run_residual_loop(
    mrf: &Mrf,
    msgs: &Messages,
    cfg: &RunConfig,
    sched: &dyn Scheduler,
    counts: Option<&[AtomicU32]>,
) -> Result<EngineStats> {
    let timer = Timer::start();
    let budget = Budget::new(cfg.time_limit_secs, cfg.max_updates);
    let eps = cfg.epsilon;

    let la = Lookahead::init(mrf, msgs);
    let ts = TaskStates::new(mrf.num_messages());
    let term = Termination::new();
    let timed_out = AtomicBool::new(false);

    // Seed the scheduler.
    {
        let mut rng = Xoshiro256::stream(cfg.seed, 0xFEED);
        for e in 0..mrf.num_messages() as u32 {
            let p = priority(la.residual(e), e, counts);
            if p >= eps {
                term.before_insert();
                sched.insert(Entry { prio: p, task: e, epoch: ts.epoch(e) }, &mut rng);
            }
        }
    }

    let per_thread = run_workers(cfg.threads, |tid| {
        let mut rng = Xoshiro256::stream(cfg.seed, 1000 + tid as u64);
        let mut c = Counters::default();
        let mut since_flush: u64 = 0;
        let mut idle_spins: u32 = 0;

        while !term.is_done() {
            term.enter();
            let popped = sched.pop(&mut rng);
            match popped {
                Some(ent) => {
                    term.after_pop();
                    c.pops += 1;
                    idle_spins = 0;
                    if ent.epoch != ts.epoch(ent.task) {
                        c.stale_pops += 1;
                        term.exit();
                        continue;
                    }
                    if !ts.try_claim(ent.task, ent.epoch) {
                        c.claim_failures += 1;
                        term.exit();
                        continue;
                    }
                    // Commit the precomputed update.
                    let res = la.commit(mrf, msgs, ent.task);
                    c.updates += 1;
                    since_flush += 1;
                    if res >= eps {
                        c.useful_updates += 1;
                    } else {
                        c.wasted_pops += 1;
                    }
                    if let Some(counts) = counts {
                        counts[ent.task as usize].fetch_add(1, Ordering::Relaxed);
                    }
                    // Refresh + requeue the affected out-edges of dst.
                    let j = mrf.graph.edge_dst[ent.task as usize] as usize;
                    let rev = mrf.graph.reverse(ent.task);
                    for s in mrf.graph.slots(j) {
                        let k = mrf.graph.adj_out[s];
                        if k == rev {
                            continue;
                        }
                        let r = la.refresh(mrf, msgs, k);
                        let p = priority(r, k, counts);
                        let epoch = ts.bump(k);
                        if p >= eps {
                            term.before_insert();
                            sched.insert(Entry { prio: p, task: k, epoch }, &mut rng);
                            c.inserts += 1;
                        }
                    }
                    ts.release(ent.task);
                    term.exit();

                    // Periodic budget check (updates flushed in batches).
                    if since_flush >= 256 {
                        let g = term
                            .global_updates
                            .fetch_add(since_flush, Ordering::Relaxed)
                            + since_flush;
                        since_flush = 0;
                        if budget.expired(g) {
                            timed_out.store(true, Ordering::Release);
                            term.set_done();
                        }
                    }
                }
                None => {
                    term.exit();
                    if term.quiescent() {
                        term.try_verify(|| {
                            // Full refresh of every edge repairs any
                            // residual lost to benign write races.
                            let mut found = false;
                            for e in 0..mrf.num_messages() as u32 {
                                let r = la.refresh(mrf, msgs, e);
                                let p = priority(r, e, counts);
                                if p >= eps {
                                    let epoch = ts.bump(e);
                                    term.before_insert();
                                    sched.insert(Entry { prio: p, task: e, epoch }, &mut rng);
                                    found = true;
                                }
                            }
                            !found
                        });
                    } else {
                        idle_spins += 1;
                        if idle_spins > 64 {
                            std::thread::yield_now();
                        } else {
                            std::hint::spin_loop();
                        }
                        // An idle thread must also enforce the wall clock,
                        // otherwise a deadlocked run would never stop.
                        if budget.expired(term.global_updates.load(Ordering::Relaxed)) {
                            timed_out.store(true, Ordering::Release);
                            term.set_done();
                        }
                    }
                }
            }
        }
        c
    });

    let final_max = la.max_residual();
    Ok(EngineStats {
        converged: !timed_out.load(Ordering::Acquire),
        wall_secs: timer.elapsed_secs(),
        metrics: MetricsReport::aggregate(&per_thread),
        final_max_priority: final_max,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::{all_marginals, exact_marginals, max_marginal_diff};
    use crate::configio::{AlgorithmSpec, ModelSpec};
    use crate::model::builders;

    fn run_with(
        engine: &ResidualEngine,
        spec: ModelSpec,
        threads: usize,
        seed: u64,
    ) -> (Mrf, Messages, EngineStats) {
        let mrf = builders::build(&spec, seed);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::RelaxedResidual)
            .with_threads(threads)
            .with_seed(seed);
        let stats = engine.run(&mrf, &msgs, &cfg).unwrap();
        (mrf, msgs, stats)
    }

    #[test]
    fn relaxed_tree_converges_near_optimal() {
        let (_, _, stats) =
            run_with(&ResidualEngine::relaxed(), ModelSpec::Tree { n: 255 }, 1, 1);
        assert!(stats.converged);
        // Relaxation may waste a few updates but not blow up (Lemma 2).
        assert!(stats.metrics.total.updates >= 254);
        assert!(stats.metrics.total.updates < 2 * 254, "{}", stats.metrics.total.updates);
        assert!(stats.final_max_priority < 1e-5);
    }

    #[test]
    fn relaxed_matches_exact_marginals_on_tree() {
        let (mrf, msgs, stats) =
            run_with(&ResidualEngine::relaxed(), ModelSpec::Tree { n: 15 }, 2, 3);
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);
        let exact = exact_marginals(&mrf, 1 << 20).unwrap();
        assert!(max_marginal_diff(&bp, &exact) < 1e-4);
    }

    #[test]
    fn coarse_grained_converges_multithreaded() {
        let (mrf, msgs, stats) =
            run_with(&ResidualEngine::coarse_grained(), ModelSpec::Ising { n: 6 }, 4, 5);
        assert!(stats.converged, "max prio {}", stats.final_max_priority);
        let bp = all_marginals(&mrf, &msgs);
        for m in &bp {
            assert!((m.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn relaxed_ising_multithreaded_matches_sequential_marginals() {
        let spec = ModelSpec::Ising { n: 6 };
        let (mrf, msgs, stats) = run_with(&ResidualEngine::relaxed(), spec.clone(), 4, 7);
        assert!(stats.converged);
        let relaxed_marg = all_marginals(&mrf, &msgs);

        let mrf2 = builders::build(&spec, 7);
        let msgs2 = Messages::uniform(&mrf2);
        let cfg2 = RunConfig::new(spec, AlgorithmSpec::SequentialResidual).with_seed(7);
        let s2 = super::super::sequential::SequentialResidual.run(&mrf2, &msgs2, &cfg2).unwrap();
        assert!(s2.converged);
        let seq_marg = all_marginals(&mrf2, &msgs2);

        // Same fixed point (within convergence tolerance amplification).
        assert!(
            max_marginal_diff(&relaxed_marg, &seq_marg) < 1e-2,
            "diff = {}",
            max_marginal_diff(&relaxed_marg, &seq_marg)
        );
    }

    #[test]
    fn weight_decay_converges() {
        let (_, _, stats) =
            run_with(&ResidualEngine::weight_decay(), ModelSpec::Potts { n: 6 }, 2, 9);
        assert!(stats.converged);
        assert!(stats.metrics.total.updates > 0);
    }

    #[test]
    fn ldpc_decodes_relaxed_multithreaded() {
        let inst = builders::ldpc::build(60, 0.05, 11);
        let msgs = Messages::uniform(&inst.mrf);
        let cfg = RunConfig::new(
            ModelSpec::Ldpc { n: 60, flip_prob: 0.05 },
            AlgorithmSpec::RelaxedResidual,
        )
        .with_threads(4)
        .with_seed(11);
        let stats = ResidualEngine::relaxed().run(&inst.mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bits = crate::bp::decode_bits(&inst.mrf, &msgs, inst.num_vars);
        assert_eq!(bits, inst.sent);
    }

    #[test]
    fn budget_timeout_reported() {
        let spec = ModelSpec::Ising { n: 12 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::RelaxedResidual)
            .with_threads(2)
            .with_max_updates(300);
        let stats = ResidualEngine::relaxed().run(&mrf, &msgs, &cfg).unwrap();
        assert!(!stats.converged);
    }

    #[test]
    fn update_overhead_vs_sequential_small() {
        // Table 3's phenomenon in miniature: relaxed performs only slightly
        // more updates than the sequential baseline.
        let spec = ModelSpec::Ising { n: 8 };
        let mrf = builders::build(&spec, 21);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec.clone(), AlgorithmSpec::SequentialResidual).with_seed(21);
        let seq = super::super::sequential::SequentialResidual.run(&mrf, &msgs, &cfg).unwrap();

        let mrf2 = builders::build(&spec, 21);
        let msgs2 = Messages::uniform(&mrf2);
        let cfg2 = RunConfig::new(spec, AlgorithmSpec::RelaxedResidual).with_seed(21);
        let rel = ResidualEngine::relaxed().run(&mrf2, &msgs2, &cfg2).unwrap();

        assert!(seq.converged && rel.converged);
        let ratio = rel.metrics.total.updates as f64 / seq.metrics.total.updates as f64;
        assert!(ratio < 1.6, "single-thread relaxed overhead ratio {ratio}");
    }
}
