//! The Yin–Gao "bucket" algorithm (CIKM 2014): prioritized block updates.
//!
//! Each round selects the top `0.1·|V|` vertices by the splash metric
//! (node residual) and updates all of their outgoing messages as one
//! synchronous block, then refreshes residuals. A mixed
//! synchronous/priority strategy designed for distributed settings; the
//! paper includes it as a baseline that underperforms fine-grained relaxed
//! scheduling on shared-memory CPUs.

use super::{Engine, EngineStats};
use crate::bp::{Lookahead, Messages, MsgScratch};
use crate::configio::RunConfig;
use crate::coordinator::{run_workers, Budget, Counters, MetricsReport};
use crate::model::Mrf;
use crate::util::Timer;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};

/// The Yin–Gao bucket algorithm: rounds over the top 0.1·|V| nodes.
pub struct Bucket {
    /// Fraction of vertices updated per round (paper: 0.1).
    pub fraction: f64,
}

impl Default for Bucket {
    fn default() -> Self {
        Bucket { fraction: 0.1 }
    }
}

impl Engine for Bucket {
    fn name(&self) -> String {
        "bucket".into()
    }

    fn run(&self, mrf: &Mrf, msgs: &Messages, cfg: &RunConfig) -> Result<EngineStats> {
        let timer = Timer::start();
        let budget = Budget::new(cfg.time_limit_secs, cfg.max_updates);
        let eps = cfg.epsilon;
        let n = mrf.num_nodes();
        let threads = cfg.threads.max(1);
        let block = ((n as f64 * self.fraction).ceil() as usize).max(1);

        let la = Lookahead::init(mrf, msgs, cfg.kernel);
        let mut total = Counters::default();
        let (live_l, live_p) = msgs.arena_bytes();
        let (la_l, la_p) = la.arena_bytes();
        total.msg_bytes_logical = (live_l + la_l) as u64;
        total.msg_bytes_padded = (live_p + la_p) as u64;
        let global_updates = AtomicU64::new(0);
        let mut converged = true;

        loop {
            // Node priorities (splash metric) — sequential scan, cheap
            // relative to the update work.
            let mut prio: Vec<(f64, u32)> = (0..n as u32)
                .map(|v| {
                    let mut p = 0.0f64;
                    for s in mrf.graph.slots(v as usize) {
                        p = p.max(la.residual(mrf.graph.adj_in[s]));
                    }
                    (p, v)
                })
                .collect();
            // Top `block` by priority.
            prio.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            if prio[0].0 < eps {
                break; // converged
            }
            let selected: Vec<u32> = prio
                .iter()
                .take(block)
                .filter(|(p, _)| *p >= eps)
                .map(|&(_, v)| v)
                .collect();

            // Block-update the selected vertices in parallel: apply the
            // pending incoming messages (consuming the node's splash-metric
            // priority), then push fresh outgoing messages — the vertex
            // granularity Yin–Gao's block update operates at.
            let chunk = selected.len().div_ceil(threads);
            let per_thread = run_workers(threads, |tid| {
                let mut c = Counters::default();
                let mut gather = MsgScratch::new();
                let lo = (tid * chunk).min(selected.len());
                let hi = ((tid + 1) * chunk).min(selected.len());
                for &v in &selected[lo..hi] {
                    for s in mrf.graph.slots(v as usize) {
                        let e = mrf.graph.adj_in[s];
                        let r = la.residual(e);
                        if r >= eps {
                            la.commit(mrf, msgs, e);
                            c.updates += 1;
                            c.useful_updates += 1;
                        }
                    }
                    for s in mrf.graph.slots(v as usize) {
                        let e = mrf.graph.adj_out[s];
                        let r = la.refresh(mrf, msgs, e, &mut gather);
                        la.commit(mrf, msgs, e);
                        c.updates += 1;
                        if r >= eps {
                            c.useful_updates += 1;
                        }
                    }
                }
                c
            });
            let mut round_updates = 0;
            for c in &per_thread {
                round_updates += c.updates;
                total.add(c);
            }
            total.rounds += 1;

            // Refresh residuals of every edge leaving a node that received
            // an update (dst of any committed edge = neighbors of selected).
            let mut dsts: Vec<u32> = selected
                .iter()
                .flat_map(|&v| mrf.graph.neighbors(v as usize).iter().copied())
                .collect();
            dsts.sort_unstable();
            dsts.dedup();
            let chunk2 = dsts.len().div_ceil(threads);
            run_workers(threads, |tid| {
                let mut gather = MsgScratch::new();
                let lo = (tid * chunk2).min(dsts.len());
                let hi = ((tid + 1) * chunk2).min(dsts.len());
                for &j in &dsts[lo..hi] {
                    for s in mrf.graph.slots(j as usize) {
                        la.refresh(mrf, msgs, mrf.graph.adj_out[s], &mut gather);
                    }
                }
            });

            let g = global_updates.fetch_add(round_updates, Ordering::Relaxed) + round_updates;
            if budget.expired(g) {
                converged = false;
                break;
            }
        }

        let final_max = la.max_residual();
        Ok(EngineStats {
            converged: converged && final_max < eps,
            wall_secs: timer.elapsed_secs(),
            metrics: MetricsReport::aggregate(&[total]),
            final_max_priority: final_max,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::{all_marginals, exact_marginals, max_marginal_diff};
    use crate::configio::{AlgorithmSpec, ModelSpec};
    use crate::model::builders;

    #[test]
    fn bucket_converges_on_tree() {
        let spec = ModelSpec::Tree { n: 63 };
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::Bucket).with_threads(2);
        let stats = Bucket::default().run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        assert!(stats.metrics.total.rounds > 0);
        let bp = all_marginals(&mrf, &msgs);
        for m in bp {
            assert!((m[0] - 0.1).abs() < 1e-4);
        }
    }

    #[test]
    fn bucket_matches_oracle_small_grid() {
        let spec = ModelSpec::Ising { n: 3 };
        let mrf = builders::build(&spec, 4);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::Bucket);
        let stats = Bucket::default().run(&mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);
        let exact = exact_marginals(&mrf, 1 << 20).unwrap();
        assert!(max_marginal_diff(&bp, &exact) < 0.05);
    }

    #[test]
    fn budget_respected() {
        let spec = ModelSpec::Ising { n: 8 };
        let mrf = builders::build(&spec, 2);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::Bucket).with_max_updates(10);
        let stats = Bucket::default().run(&mrf, &msgs, &cfg).unwrap();
        assert!(!stats.converged);
    }
}
