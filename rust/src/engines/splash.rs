//! The splash family (Gonzalez–Low–Guestrin 2009) — node-based tasks.
//!
//! A node's priority is its *node residual* `res(v) = max_{u∈N(v)}
//! res(μ_{u→v})`. Processing node `v` performs a **splash**: build the BFS
//! tree of depth `H` rooted at `v`, update messages in reverse-BFS order
//! (gathering information toward `v`), then in BFS order (spreading it
//! back out).
//!
//! Variants (all one [`SplashPolicy`] on the shared [`WorkerPool`]):
//! - **Splash** (paper "S H"): exact PQ, full splash (every processed node
//!   updates *all* outgoing messages);
//! - **Smart splash** ("SS"/"RSS"): only BFS-tree edges are updated —
//!   child→parent in the gather phase, parent→child in the scatter phase;
//! - **Random splash** ("RS"): the journal version's naive random queues
//!   (no rank bound) with the full splash operation;
//! - **Relaxed smart splash**: smart splash on the Multiqueue — the
//!   paper's best performer on grids.

use super::{Engine, EngineStats};
use crate::bp::{Lookahead, Messages, MsgScratch, NodeScratch};
use crate::configio::RunConfig;
use crate::coordinator::Counters;
use crate::exec::{ExecCtx, TaskPolicy, WorkerPool};
use crate::model::{EvidenceDelta, Mrf};
use crate::sched::SchedChoice;
use anyhow::Result;
use std::collections::HashSet;

/// The splash family: exact, smart, relaxed, and random variants.
pub struct SplashEngine {
    h: usize,
    smart: bool,
    choice: SchedChoice,
}

impl SplashEngine {
    /// Exact PQ splash of depth `h` (smart = BFS-tree edges only).
    pub fn exact(h: usize, smart: bool) -> Self {
        Self { h, smart, choice: SchedChoice::Exact }
    }

    /// Multiqueue splash of depth `h`.
    pub fn relaxed(h: usize, smart: bool) -> Self {
        Self { h, smart, choice: SchedChoice::Relaxed }
    }

    /// Naive random-queues splash of depth `h` (journal version).
    pub fn random(h: usize, smart: bool) -> Self {
        Self { h, smart, choice: SchedChoice::Random }
    }
}

impl Engine for SplashEngine {
    fn name(&self) -> String {
        let base = match (self.choice, self.smart) {
            (SchedChoice::Exact, false) => "splash",
            (SchedChoice::Exact, true) => "smart_splash",
            (SchedChoice::Relaxed, true) => "relaxed_smart_splash",
            (SchedChoice::Relaxed, false) => "relaxed_splash",
            (SchedChoice::Random, false) => "random_splash",
            (SchedChoice::Random, true) => "random_smart_splash",
        };
        format!("{base}_{}", self.h)
    }

    fn run(&self, mrf: &Mrf, msgs: &Messages, cfg: &RunConfig) -> Result<EngineStats> {
        self.run_observed(mrf, msgs, cfg, None)
    }

    fn run_observed(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        cfg: &RunConfig,
        observer: Option<&dyn crate::exec::RunObserver>,
    ) -> Result<EngineStats> {
        let policy = SplashPolicy::new(mrf, msgs, cfg, self.h, self.smart);
        // Budget units are splash-tree nodes, several message updates
        // each, so flush at finer granularity than message engines.
        // Splash tasks are nodes, so the partition covers the node
        // universe.
        Ok(WorkerPool::from_config(cfg, self.choice)
            .flush_every(128)
            .with_partition(crate::model::partition::for_nodes(mrf, cfg))
            .run_observed(&policy, observer))
    }

    fn resume(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        cfg: &RunConfig,
        delta: &EvidenceDelta,
        observer: Option<&dyn crate::exec::RunObserver>,
    ) -> Result<EngineStats> {
        let policy = SplashPolicy::new_delta(mrf, msgs, cfg, self.h, self.smart, delta);
        Ok(WorkerPool::from_config(cfg, self.choice)
            .flush_every(128)
            .with_partition(crate::model::partition::for_nodes(mrf, cfg))
            .run_observed(&policy, observer))
    }
}

/// Per-worker BFS and refresh buffers, reused across splashes.
pub(crate) struct SplashScratch {
    /// `(node, parent_edge or u32::MAX)` in BFS order.
    order: Vec<(u32, u32)>,
    visited: HashSet<u32>,
    /// Nodes that received a new message during the splash.
    touched: Vec<u32>,
    /// Nodes whose priority may have changed.
    affected: Vec<u32>,
    /// Fused-kernel prefix/suffix buffers (post-splash refresh).
    node: NodeScratch,
    /// Edge-wise gather buffers (splash commits + edgewise refresh).
    gather: MsgScratch,
    /// Scratch for fused refresh results / batched node requeues.
    batch: Vec<(u32, f64)>,
}

/// Node-task policy: node-residual priorities, splash processing.
pub(crate) struct SplashPolicy<'a> {
    mrf: &'a Mrf,
    msgs: &'a Messages,
    la: Lookahead,
    h: usize,
    smart: bool,
    eps: f64,
    /// Fused post-splash refresh + batched node requeues
    /// (`RunConfig::fused`).
    fused: bool,
    /// Delta warm start: seed only these node tasks (the perturbed nodes
    /// and their neighbors — the nodes whose node residual the re-priced
    /// out-edges feed). `None` = scratch run, full seed.
    seed_nodes: Option<Vec<u32>>,
}

impl<'a> SplashPolicy<'a> {
    pub(crate) fn new(
        mrf: &'a Mrf,
        msgs: &'a Messages,
        cfg: &RunConfig,
        h: usize,
        smart: bool,
    ) -> Self {
        let la = if cfg.fused {
            Lookahead::init_fused(mrf, msgs, cfg.kernel)
        } else {
            Lookahead::init(mrf, msgs, cfg.kernel)
        };
        SplashPolicy { mrf, msgs, la, h, smart, eps: cfg.epsilon, fused: cfg.fused, seed_nodes: None }
    }

    /// Warm-start policy over a resident `msgs` state: the lookahead cache
    /// is delta-primed over the perturbed nodes' out-edges, and seeding
    /// covers the perturbed nodes plus their neighbors — node residuals
    /// are maxima over *incoming* messages, so a perturbed node's out-set
    /// re-pricing raises exactly its neighbors' priorities.
    pub(crate) fn new_delta(
        mrf: &'a Mrf,
        msgs: &'a Messages,
        cfg: &RunConfig,
        h: usize,
        smart: bool,
        delta: &EvidenceDelta,
    ) -> Self {
        let nodes: Vec<u32> = delta.nodes().collect();
        let la = if cfg.fused {
            Lookahead::init_delta_fused(mrf, msgs, cfg.kernel, &nodes)
        } else {
            Lookahead::init_delta(mrf, msgs, cfg.kernel, &nodes)
        };
        let mut seed: Vec<u32> = Vec::new();
        for &i in &nodes {
            seed.push(i);
            for s in mrf.graph.slots(i as usize) {
                seed.push(mrf.graph.adj_node[s]);
            }
        }
        seed.sort_unstable();
        seed.dedup();
        SplashPolicy {
            mrf,
            msgs,
            la,
            h,
            smart,
            eps: cfg.epsilon,
            fused: cfg.fused,
            seed_nodes: Some(seed),
        }
    }

    /// Node residual: max residual over incoming messages.
    #[inline]
    fn node_priority(&self, v: u32) -> f64 {
        let mut p = 0.0f64;
        for s in self.mrf.graph.slots(v as usize) {
            p = p.max(self.la.residual(self.mrf.graph.adj_in[s]));
        }
        p
    }

    /// Commit edge `e`'s pending update and record its destination.
    fn commit(&self, e: u32, c: &mut Counters, gather: &mut MsgScratch, touched: &mut Vec<u32>) {
        let r = self.la.refresh(self.mrf, self.msgs, e, gather);
        self.la.commit(self.mrf, self.msgs, e);
        c.updates += 1;
        if r >= self.eps {
            c.useful_updates += 1;
        }
        touched.push(self.mrf.graph.edge_dst[e as usize]);
    }

    /// The splash operation rooted at `v`; returns the BFS tree size.
    fn splash(&self, v: u32, ctx: &mut ExecCtx<'_>, sc: &mut SplashScratch) -> u64 {
        ctx.counters.splashes += 1;
        sc.order.clear();
        sc.visited.clear();
        sc.touched.clear();
        sc.affected.clear();

        // BFS to depth h.
        sc.visited.insert(v);
        sc.order.push((v, u32::MAX));
        let mut frontier_start = 0usize;
        for _depth in 0..self.h {
            let frontier_end = sc.order.len();
            for idx in frontier_start..frontier_end {
                let (u, _) = sc.order[idx];
                for s in self.mrf.graph.slots(u as usize) {
                    let w = self.mrf.graph.adj_node[s];
                    if sc.visited.insert(w) {
                        // parent edge: u→w
                        sc.order.push((w, self.mrf.graph.adj_out[s]));
                    }
                }
            }
            frontier_start = frontier_end;
        }

        // Gather: reverse BFS order.
        for &(u, pe) in sc.order.iter().rev() {
            if self.smart {
                if pe != u32::MAX {
                    // child→parent is the reverse of the parent→child tree
                    // edge.
                    let rev = self.mrf.graph.reverse(pe);
                    self.commit(rev, ctx.counters, &mut sc.gather, &mut sc.touched);
                }
            } else {
                for s in self.mrf.graph.slots(u as usize) {
                    let e_out = self.mrf.graph.adj_out[s];
                    self.commit(e_out, ctx.counters, &mut sc.gather, &mut sc.touched);
                }
            }
        }
        // Scatter: BFS order.
        for &(u, pe) in sc.order.iter() {
            if self.smart {
                if pe != u32::MAX {
                    self.commit(pe, ctx.counters, &mut sc.gather, &mut sc.touched);
                }
            } else {
                for s in self.mrf.graph.slots(u as usize) {
                    let e_out = self.mrf.graph.adj_out[s];
                    self.commit(e_out, ctx.counters, &mut sc.gather, &mut sc.touched);
                }
            }
        }

        // Refresh residuals of every node that received a new message and
        // requeue the nodes whose priority may have changed.
        sc.touched.sort_unstable();
        sc.touched.dedup();
        if self.fused {
            // One fused O(deg) pass per touched node instead of one full
            // gather per out-edge (the splash fan-out is exactly a node's
            // whole out-set, the fused kernel's natural unit).
            for &j in sc.touched.iter() {
                sc.batch.clear();
                self.la
                    .refresh_node(self.mrf, self.msgs, j, None, &mut sc.node, &mut sc.batch);
                ctx.counters.refreshes += sc.batch.len() as u64;
                for s in self.mrf.graph.slots(j as usize) {
                    sc.affected.push(self.mrf.graph.adj_node[s]);
                }
                sc.affected.push(j);
            }
        } else {
            for &j in sc.touched.iter() {
                for s in self.mrf.graph.slots(j as usize) {
                    self.la.refresh(self.mrf, self.msgs, self.mrf.graph.adj_out[s], &mut sc.gather);
                    ctx.counters.refreshes += 1;
                    sc.affected.push(self.mrf.graph.adj_node[s]);
                }
                sc.affected.push(j);
            }
        }
        sc.affected.sort_unstable();
        sc.affected.dedup();
        if self.fused {
            // Batched node requeues: one scheduler visit for the splash's
            // whole activation set.
            sc.batch.clear();
            for &w in &sc.affected {
                sc.batch.push((w, self.node_priority(w)));
            }
            ctx.requeue_batch(&sc.batch);
        } else {
            for &w in &sc.affected {
                ctx.requeue(w, self.node_priority(w));
            }
        }

        sc.order.len() as u64
    }
}

impl TaskPolicy for SplashPolicy<'_> {
    type Scratch = SplashScratch;

    fn num_tasks(&self) -> usize {
        self.mrf.num_nodes()
    }

    fn make_scratch(&self) -> Self::Scratch {
        SplashScratch {
            order: Vec::new(),
            visited: HashSet::new(),
            touched: Vec::new(),
            affected: Vec::new(),
            node: NodeScratch::new(),
            batch: Vec::new(),
        }
    }

    fn seed(&self, ctx: &mut ExecCtx<'_>) {
        match &self.seed_nodes {
            None => {
                for v in 0..self.mrf.num_nodes() as u32 {
                    ctx.requeue(v, self.node_priority(v));
                }
            }
            Some(nodes) => {
                // Delta warm start: one shard-grouped batch over the
                // perturbed nodes and their neighbors.
                let batch: Vec<(u32, f64)> =
                    nodes.iter().map(|&v| (v, self.node_priority(v))).collect();
                ctx.counters.tasks_touched += batch.len() as u64;
                ctx.requeue_batch(&batch);
            }
        }
    }

    fn process(&self, tasks: &[u32], ctx: &mut ExecCtx<'_>, sc: &mut SplashScratch) -> u64 {
        let mut work = 0;
        for &v in tasks {
            if self.node_priority(v) < self.eps {
                // Priority decayed since insertion — a wasted scheduler
                // access, no splash performed.
                ctx.counters.wasted_pops += 1;
                continue;
            }
            work += self.splash(v, ctx, sc);
        }
        work
    }

    fn verify_sweep(&self, ctx: &mut ExecCtx<'_>) -> bool {
        let mut found = false;
        if self.fused {
            let mut sc = NodeScratch::new();
            let mut batch = Vec::new();
            for j in 0..self.mrf.num_nodes() as u32 {
                self.la.refresh_node(self.mrf, self.msgs, j, None, &mut sc, &mut batch);
                batch.clear();
            }
        } else {
            let mut gather = MsgScratch::new();
            for e in 0..self.mrf.num_messages() as u32 {
                self.la.refresh(self.mrf, self.msgs, e, &mut gather);
            }
        }
        for v in 0..self.mrf.num_nodes() as u32 {
            if ctx.requeue(v, self.node_priority(v)) {
                found = true;
            }
        }
        !found
    }

    fn arena_bytes(&self) -> (u64, u64) {
        let (live_l, live_p) = self.msgs.arena_bytes();
        let (la_l, la_p) = self.la.arena_bytes();
        ((live_l + la_l) as u64, (live_p + la_p) as u64)
    }

    fn final_priority(&self) -> f64 {
        self.la.max_residual()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::{all_marginals, max_marginal_diff};
    use crate::configio::{AlgorithmSpec, ModelSpec};
    use crate::model::builders;

    fn run_engine(
        engine: &SplashEngine,
        spec: ModelSpec,
        threads: usize,
        seed: u64,
    ) -> (Mrf, Messages, EngineStats) {
        let mrf = builders::build(&spec, seed);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::Splash { h: 2 })
            .with_threads(threads)
            .with_seed(seed);
        let stats = engine.run(&mrf, &msgs, &cfg).unwrap();
        (mrf, msgs, stats)
    }

    #[test]
    fn exact_splash_tree_marginals() {
        let (mrf, msgs, stats) =
            run_engine(&SplashEngine::exact(2, false), ModelSpec::Tree { n: 31 }, 1, 1);
        assert!(stats.converged);
        assert!(stats.metrics.total.splashes > 0);
        let bp = all_marginals(&mrf, &msgs);
        for m in bp {
            assert!((m[0] - 0.1).abs() < 1e-4);
        }
    }

    #[test]
    fn smart_splash_fewer_updates_than_full() {
        let (_, _, full) =
            run_engine(&SplashEngine::exact(2, false), ModelSpec::Ising { n: 6 }, 1, 5);
        let (_, _, smart) =
            run_engine(&SplashEngine::exact(2, true), ModelSpec::Ising { n: 6 }, 1, 5);
        assert!(full.converged && smart.converged);
        assert!(
            smart.metrics.total.updates < full.metrics.total.updates,
            "smart {} !< full {}",
            smart.metrics.total.updates,
            full.metrics.total.updates
        );
    }

    #[test]
    fn relaxed_smart_splash_multithreaded_matches_residual_fixed_point() {
        // Schedules share the BP fixed point; compare against sequential
        // residual rather than the exact oracle (loopy BP bias is schedule-
        // independent but can exceed oracle tolerances on tight grids).
        let (mrf, msgs, stats) =
            run_engine(&SplashEngine::relaxed(2, true), ModelSpec::Ising { n: 4 }, 4, 7);
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);

        let mrf2 = crate::model::builders::build(&ModelSpec::Ising { n: 4 }, 7);
        let msgs2 = Messages::uniform(&mrf2);
        let cfg2 = RunConfig::new(ModelSpec::Ising { n: 4 }, AlgorithmSpec::SequentialResidual)
            .with_seed(7);
        let s2 = crate::engines::sequential::SequentialResidual
            .run(&mrf2, &msgs2, &cfg2)
            .unwrap();
        assert!(s2.converged);
        let seq = all_marginals(&mrf2, &msgs2);
        assert!(
            max_marginal_diff(&bp, &seq) < 1e-2,
            "diff = {}",
            max_marginal_diff(&bp, &seq)
        );
    }

    #[test]
    fn random_splash_converges() {
        let (_, _, stats) =
            run_engine(&SplashEngine::random(2, false), ModelSpec::Ising { n: 5 }, 2, 9);
        assert!(stats.converged);
    }

    #[test]
    fn splash_depth_bounds_tree_size() {
        // On a path, a splash of depth H from an end touches H+1 nodes; the
        // updates per splash are bounded accordingly (smart: 2 per tree
        // edge).
        let (_, _, stats) =
            run_engine(&SplashEngine::exact(3, true), ModelSpec::Path { n: 50 }, 1, 1);
        assert!(stats.converged);
        // Path with root evidence needs ~n useful updates; smart splash
        // re-walks overlapping trees, so allow generous slack but verify it
        // is not quadratic.
        assert!(stats.metrics.total.updates < 50 * 20);
    }

    #[test]
    fn ldpc_smart_splash_decodes() {
        let inst = builders::ldpc::build(40, 0.05, 3);
        let msgs = Messages::uniform(&inst.mrf);
        let cfg = RunConfig::new(
            ModelSpec::Ldpc { n: 40, flip_prob: 0.05 },
            AlgorithmSpec::RelaxedSmartSplash { h: 2 },
        )
        .with_threads(2);
        let stats = SplashEngine::relaxed(2, true).run(&inst.mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bits = crate::bp::decode_bits(&inst.mrf, &msgs, inst.num_vars);
        assert_eq!(bits, inst.sent);
    }
}
