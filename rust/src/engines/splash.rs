//! The splash family (Gonzalez–Low–Guestrin 2009) — node-based tasks.
//!
//! A node's priority is its *node residual* `res(v) = max_{u∈N(v)}
//! res(μ_{u→v})`. Processing node `v` performs a **splash**: build the BFS
//! tree of depth `H` rooted at `v`, update messages in reverse-BFS order
//! (gathering information toward `v`), then in BFS order (spreading it
//! back out).
//!
//! Variants (all sharing one worker loop):
//! - **Splash** (paper "S H"): exact PQ, full splash (every processed node
//!   updates *all* outgoing messages);
//! - **Smart splash** ("SS"/"RSS"): only BFS-tree edges are updated —
//!   child→parent in the gather phase, parent→child in the scatter phase;
//! - **Random splash** ("RS"): the journal version's naive random queues
//!   (no rank bound) with the full splash operation;
//! - **Relaxed smart splash**: smart splash on the Multiqueue — the
//!   paper's best performer on grids.

use super::{Engine, EngineStats};
use crate::bp::{Lookahead, Messages};
use crate::configio::RunConfig;
use crate::coordinator::{run_workers, Budget, Counters, MetricsReport, Termination};
use crate::model::Mrf;
use crate::sched::{Entry, ExactQueue, Multiqueue, RandomQueues, Scheduler, TaskStates};
use crate::util::{Timer, Xoshiro256};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SchedKind {
    Exact,
    Multi,
    Random,
}

pub struct SplashEngine {
    h: usize,
    smart: bool,
    kind: SchedKind,
}

impl SplashEngine {
    pub fn exact(h: usize, smart: bool) -> Self {
        Self { h, smart, kind: SchedKind::Exact }
    }

    pub fn relaxed(h: usize, smart: bool) -> Self {
        Self { h, smart, kind: SchedKind::Multi }
    }

    pub fn random(h: usize, smart: bool) -> Self {
        Self { h, smart, kind: SchedKind::Random }
    }
}

/// Node residual: max residual over incoming messages.
#[inline]
fn node_priority(mrf: &Mrf, la: &Lookahead, v: u32) -> f64 {
    let mut p = 0.0f64;
    for s in mrf.graph.slots(v as usize) {
        p = p.max(la.residual(mrf.graph.adj_in[s]));
    }
    p
}

impl Engine for SplashEngine {
    fn name(&self) -> String {
        let base = match (self.kind, self.smart) {
            (SchedKind::Exact, false) => "splash",
            (SchedKind::Exact, true) => "smart_splash",
            (SchedKind::Multi, true) => "relaxed_smart_splash",
            (SchedKind::Multi, false) => "relaxed_splash",
            (SchedKind::Random, false) => "random_splash",
            (SchedKind::Random, true) => "random_smart_splash",
        };
        format!("{base}_{}", self.h)
    }

    fn run(&self, mrf: &Mrf, msgs: &Messages, cfg: &RunConfig) -> Result<EngineStats> {
        let timer = Timer::start();
        let budget = Budget::new(cfg.time_limit_secs, cfg.max_updates);
        let eps = cfg.epsilon;
        let n = mrf.num_nodes();

        let sched: Box<dyn Scheduler> = match self.kind {
            SchedKind::Exact => Box::new(ExactQueue::with_capacity(n)),
            SchedKind::Multi => {
                Box::new(Multiqueue::for_threads(cfg.threads, cfg.queues_per_thread))
            }
            // The journal version: p exact queues, random insert/delete.
            SchedKind::Random => Box::new(RandomQueues::new(cfg.threads.max(2))),
        };
        let sched = sched.as_ref();

        let la = Lookahead::init(mrf, msgs);
        let ts = TaskStates::new(n);
        let term = Termination::new();
        let timed_out = AtomicBool::new(false);

        // Seed with all nodes above threshold.
        {
            let mut rng = Xoshiro256::stream(cfg.seed, 0x5A5A);
            for v in 0..n as u32 {
                let p = node_priority(mrf, &la, v);
                if p >= eps {
                    term.before_insert();
                    sched.insert(Entry { prio: p, task: v, epoch: ts.epoch(v) }, &mut rng);
                }
            }
        }

        let h = self.h;
        let smart = self.smart;

        let per_thread = run_workers(cfg.threads, |tid| {
            let mut rng = Xoshiro256::stream(cfg.seed, 3000 + tid as u64);
            let mut c = Counters::default();
            let mut since_flush: u64 = 0;
            // Scratch reused across splashes.
            let mut order: Vec<(u32, u32)> = Vec::new(); // (node, parent_edge or MAX)
            let mut visited: HashMap<u32, ()> = HashMap::new();
            let mut touched: Vec<u32> = Vec::new();

            while !term.is_done() {
                term.enter();
                match sched.pop(&mut rng) {
                    Some(ent) => {
                        term.after_pop();
                        c.pops += 1;
                        if ent.epoch != ts.epoch(ent.task) {
                            c.stale_pops += 1;
                            term.exit();
                            continue;
                        }
                        if !ts.try_claim(ent.task, ent.epoch) {
                            c.claim_failures += 1;
                            term.exit();
                            continue;
                        }
                        let v = ent.task;
                        if node_priority(mrf, &la, v) < eps {
                            // Priority decayed since insertion — a wasted
                            // scheduler access, no splash performed.
                            c.wasted_pops += 1;
                            ts.release(v);
                            term.exit();
                            continue;
                        }

                        // ---- Splash operation ----
                        c.splashes += 1;
                        order.clear();
                        visited.clear();
                        touched.clear();
                        // BFS to depth h.
                        visited.insert(v, ());
                        order.push((v, u32::MAX));
                        let mut frontier_start = 0usize;
                        for _depth in 0..h {
                            let frontier_end = order.len();
                            for idx in frontier_start..frontier_end {
                                let (u, _) = order[idx];
                                for s in mrf.graph.slots(u as usize) {
                                    let w = mrf.graph.adj_node[s];
                                    if !visited.contains_key(&w) {
                                        visited.insert(w, ());
                                        // parent edge: u→w
                                        order.push((w, mrf.graph.adj_out[s]));
                                    }
                                }
                            }
                            frontier_start = frontier_end;
                        }

                        let commit = |e: u32, c: &mut Counters, touched: &mut Vec<u32>| {
                            let r = la.refresh(mrf, msgs, e);
                            la.commit(mrf, msgs, e);
                            c.updates += 1;
                            if r >= eps {
                                c.useful_updates += 1;
                            }
                            touched.push(mrf.graph.edge_dst[e as usize]);
                        };

                        // Gather: reverse BFS order.
                        for &(u, pe) in order.iter().rev() {
                            if smart {
                                if pe != u32::MAX {
                                    // child→parent is the reverse of the
                                    // parent→child tree edge.
                                    commit(mrf.graph.reverse(pe), &mut c, &mut touched);
                                }
                            } else {
                                for s in mrf.graph.slots(u as usize) {
                                    commit(mrf.graph.adj_out[s], &mut c, &mut touched);
                                }
                            }
                        }
                        // Scatter: BFS order.
                        for &(u, pe) in order.iter() {
                            if smart {
                                if pe != u32::MAX {
                                    commit(pe, &mut c, &mut touched);
                                }
                            } else {
                                for s in mrf.graph.slots(u as usize) {
                                    commit(mrf.graph.adj_out[s], &mut c, &mut touched);
                                }
                            }
                        }

                        // ---- Refresh residuals and requeue priorities ----
                        touched.sort_unstable();
                        touched.dedup();
                        // Refresh out-edges of every node that received a
                        // new message; collect the nodes whose priority may
                        // have changed.
                        let mut affected_nodes: Vec<u32> = Vec::new();
                        for &j in touched.iter() {
                            for s in mrf.graph.slots(j as usize) {
                                la.refresh(mrf, msgs, mrf.graph.adj_out[s]);
                                affected_nodes.push(mrf.graph.adj_node[s]);
                            }
                            affected_nodes.push(j);
                        }
                        affected_nodes.sort_unstable();
                        affected_nodes.dedup();
                        for &w in &affected_nodes {
                            let p = node_priority(mrf, &la, w);
                            let epoch = ts.bump(w);
                            if p >= eps {
                                term.before_insert();
                                sched.insert(Entry { prio: p, task: w, epoch }, &mut rng);
                                c.inserts += 1;
                            }
                        }

                        ts.release(v);
                        term.exit();

                        since_flush += order.len() as u64;
                        if since_flush >= 128 {
                            let g = term
                                .global_updates
                                .fetch_add(since_flush, Ordering::Relaxed)
                                + since_flush;
                            since_flush = 0;
                            if budget.expired(g) {
                                timed_out.store(true, Ordering::Release);
                                term.set_done();
                            }
                        }
                    }
                    None => {
                        term.exit();
                        if term.quiescent() {
                            term.try_verify(|| {
                                let mut found = false;
                                for e in 0..mrf.num_messages() as u32 {
                                    la.refresh(mrf, msgs, e);
                                }
                                for v in 0..n as u32 {
                                    let p = node_priority(mrf, &la, v);
                                    if p >= eps {
                                        let epoch = ts.bump(v);
                                        term.before_insert();
                                        sched.insert(
                                            Entry { prio: p, task: v, epoch },
                                            &mut rng,
                                        );
                                        found = true;
                                    }
                                }
                                !found
                            });
                        } else {
                            std::thread::yield_now();
                            if budget.expired(term.global_updates.load(Ordering::Relaxed)) {
                                timed_out.store(true, Ordering::Release);
                                term.set_done();
                            }
                        }
                    }
                }
            }
            c
        });

        let final_max = la.max_residual();
        Ok(EngineStats {
            converged: !timed_out.load(Ordering::Acquire),
            wall_secs: timer.elapsed_secs(),
            metrics: MetricsReport::aggregate(&per_thread),
            final_max_priority: final_max,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::{all_marginals, max_marginal_diff};
    use crate::configio::{AlgorithmSpec, ModelSpec};
    use crate::model::builders;

    fn run_engine(
        engine: &SplashEngine,
        spec: ModelSpec,
        threads: usize,
        seed: u64,
    ) -> (Mrf, Messages, EngineStats) {
        let mrf = builders::build(&spec, seed);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::Splash { h: 2 })
            .with_threads(threads)
            .with_seed(seed);
        let stats = engine.run(&mrf, &msgs, &cfg).unwrap();
        (mrf, msgs, stats)
    }

    #[test]
    fn exact_splash_tree_marginals() {
        let (mrf, msgs, stats) =
            run_engine(&SplashEngine::exact(2, false), ModelSpec::Tree { n: 31 }, 1, 1);
        assert!(stats.converged);
        assert!(stats.metrics.total.splashes > 0);
        let bp = all_marginals(&mrf, &msgs);
        for m in bp {
            assert!((m[0] - 0.1).abs() < 1e-4);
        }
    }

    #[test]
    fn smart_splash_fewer_updates_than_full() {
        let (_, _, full) =
            run_engine(&SplashEngine::exact(2, false), ModelSpec::Ising { n: 6 }, 1, 5);
        let (_, _, smart) =
            run_engine(&SplashEngine::exact(2, true), ModelSpec::Ising { n: 6 }, 1, 5);
        assert!(full.converged && smart.converged);
        assert!(
            smart.metrics.total.updates < full.metrics.total.updates,
            "smart {} !< full {}",
            smart.metrics.total.updates,
            full.metrics.total.updates
        );
    }

    #[test]
    fn relaxed_smart_splash_multithreaded_matches_residual_fixed_point() {
        // Schedules share the BP fixed point; compare against sequential
        // residual rather than the exact oracle (loopy BP bias is schedule-
        // independent but can exceed oracle tolerances on tight grids).
        let (mrf, msgs, stats) =
            run_engine(&SplashEngine::relaxed(2, true), ModelSpec::Ising { n: 4 }, 4, 7);
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);

        let mrf2 = crate::model::builders::build(&ModelSpec::Ising { n: 4 }, 7);
        let msgs2 = Messages::uniform(&mrf2);
        let cfg2 = RunConfig::new(ModelSpec::Ising { n: 4 }, AlgorithmSpec::SequentialResidual)
            .with_seed(7);
        let s2 = crate::engines::sequential::SequentialResidual
            .run(&mrf2, &msgs2, &cfg2)
            .unwrap();
        assert!(s2.converged);
        let seq = all_marginals(&mrf2, &msgs2);
        assert!(
            max_marginal_diff(&bp, &seq) < 1e-2,
            "diff = {}",
            max_marginal_diff(&bp, &seq)
        );
    }

    #[test]
    fn random_splash_converges() {
        let (_, _, stats) =
            run_engine(&SplashEngine::random(2, false), ModelSpec::Ising { n: 5 }, 2, 9);
        assert!(stats.converged);
    }

    #[test]
    fn splash_depth_bounds_tree_size() {
        // On a path, a splash of depth H from an end touches H+1 nodes; the
        // updates per splash are bounded accordingly (smart: 2 per tree
        // edge).
        let (_, _, stats) =
            run_engine(&SplashEngine::exact(3, true), ModelSpec::Path { n: 50 }, 1, 1);
        assert!(stats.converged);
        // Path with root evidence needs ~n useful updates; smart splash
        // re-walks overlapping trees, so allow generous slack but verify it
        // is not quadratic.
        assert!(stats.metrics.total.updates < 50 * 20);
    }

    #[test]
    fn ldpc_smart_splash_decodes() {
        let inst = builders::ldpc::build(40, 0.05, 3);
        let msgs = Messages::uniform(&inst.mrf);
        let cfg = RunConfig::new(
            ModelSpec::Ldpc { n: 40, flip_prob: 0.05 },
            AlgorithmSpec::RelaxedSmartSplash { h: 2 },
        )
        .with_threads(2);
        let stats = SplashEngine::relaxed(2, true).run(&inst.mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bits = crate::bp::decode_bits(&inst.mrf, &msgs, inst.num_vars);
        assert_eq!(bits, inst.sent);
    }
}
