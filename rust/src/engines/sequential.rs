//! Sequential residual belief propagation — the exact baseline that every
//! table in the paper normalizes against (Elidan–McGraw–Koller 2006).
//!
//! A single thread repeatedly commits the message with the largest
//! residual. Uses the position-tracking [`IndexedHeap`] with in-place
//! priority updates — no stale-entry churn (a ~1.4× baseline throughput
//! win over lazy entries; see EXPERIMENTS.md §Perf). Bit-for-bit
//! deterministic given the model.

use super::{Engine, EngineStats};
use crate::bp::{Lookahead, Messages, MsgScratch, NodeScratch};
use crate::configio::RunConfig;
use crate::coordinator::{Budget, Counters, MetricsReport};
use crate::exec::RunObserver;
use crate::model::{EvidenceDelta, Mrf};
use crate::sched::IndexedHeap;
use crate::util::Timer;
use anyhow::Result;

/// The sequential exact-residual baseline.
pub struct SequentialResidual;

impl Engine for SequentialResidual {
    fn name(&self) -> String {
        "residual".into()
    }

    fn run(&self, mrf: &Mrf, msgs: &Messages, cfg: &RunConfig) -> Result<EngineStats> {
        self.run_observed(mrf, msgs, cfg, None)
    }

    fn run_observed(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        cfg: &RunConfig,
        observer: Option<&dyn RunObserver>,
    ) -> Result<EngineStats> {
        self.run_inner(mrf, msgs, cfg, None, observer)
    }

    fn resume(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        cfg: &RunConfig,
        delta: &EvidenceDelta,
        observer: Option<&dyn RunObserver>,
    ) -> Result<EngineStats> {
        let nodes: Vec<u32> = delta.nodes().collect();
        self.run_inner(mrf, msgs, cfg, Some(&nodes), observer)
    }
}

impl SequentialResidual {
    fn run_inner(
        &self,
        mrf: &Mrf,
        msgs: &Messages,
        cfg: &RunConfig,
        seed_nodes: Option<&[u32]>,
        observer: Option<&dyn RunObserver>,
    ) -> Result<EngineStats> {
        let timer = Timer::start();
        let budget = Budget::new(cfg.time_limit_secs, cfg.max_updates);
        let eps = cfg.epsilon;

        // Both kernel axes apply to the baseline too, so fused-vs-edgewise
        // and simd-vs-scalar comparisons against it measure scheduling,
        // not kernel, effects. A delta resume primes the lookahead from the
        // resident state and prices only the perturbed frontier.
        let la = match (seed_nodes, cfg.fused) {
            (Some(nodes), true) => Lookahead::init_delta_fused(mrf, msgs, cfg.kernel, nodes),
            (Some(nodes), false) => Lookahead::init_delta(mrf, msgs, cfg.kernel, nodes),
            (None, true) => Lookahead::init_fused(mrf, msgs, cfg.kernel),
            (None, false) => Lookahead::init(mrf, msgs, cfg.kernel),
        };
        let mut heap = IndexedHeap::new(mrf.num_messages());
        let mut c = Counters::default();
        let (live_l, live_p) = msgs.arena_bytes();
        let (la_l, la_p) = la.arena_bytes();
        c.msg_bytes_logical = (live_l + la_l) as u64;
        c.msg_bytes_padded = (live_p + la_p) as u64;
        let mut node_scratch = NodeScratch::new();
        let mut gather = MsgScratch::new();
        let mut refreshed: Vec<(u32, f64)> = Vec::new();

        match seed_nodes {
            None => {
                for e in 0..mrf.num_messages() as u32 {
                    let r = la.residual(e);
                    if r >= eps {
                        heap.update(e, r);
                        c.inserts += 1;
                    }
                }
            }
            Some(nodes) => {
                // Delta warm start: only the out-edges of perturbed nodes
                // carry non-zero residuals (everything else is bitwise at
                // the resident fixed point), so only they can seed work.
                for &i in nodes {
                    for s in mrf.graph.slots(i as usize) {
                        let e = mrf.graph.adj_out[s];
                        c.tasks_touched += 1;
                        let r = la.residual(e);
                        if r >= eps {
                            heap.update(e, r);
                            c.inserts += 1;
                        }
                    }
                }
            }
        }

        // Single-threaded engine: there is no pool to host a sampler
        // thread, so convergence samples are taken inline at the observer's
        // tick cadence (checked every `OBSERVE_EVERY` updates; the elapsed
        // read is one clock call). Like the pool sampler, emit one sample
        // at the start and one from the final state, so even sub-tick runs
        // trace at least two points.
        let tick = observer.map(|o| o.tick().as_secs_f64().max(1e-4));
        let mut last_sample = 0.0f64;
        const OBSERVE_EVERY: u64 = 256;
        if let Some(obs) = observer {
            obs.sample(timer.elapsed_secs(), &c, heap.peek().map_or(0.0, |(_, p)| p));
        }

        let mut converged = true;
        while let Some((task, res)) = heap.pop() {
            c.pops += 1;
            // Commit the top message.
            la.commit(mrf, msgs, task);
            c.updates += 1;
            if res >= eps {
                c.useful_updates += 1;
            } else {
                c.wasted_pops += 1;
            }
            // Refresh affected messages and update their heap slots.
            let j = mrf.graph.edge_dst[task as usize];
            let rev = mrf.graph.reverse(task);
            if cfg.fused {
                refreshed.clear();
                la.refresh_node(mrf, msgs, j, Some(rev), &mut node_scratch, &mut refreshed);
                c.refreshes += refreshed.len() as u64;
                for &(k, r) in &refreshed {
                    if r >= eps {
                        heap.update(k, r);
                        c.inserts += 1;
                    } else {
                        heap.remove(k);
                    }
                }
            } else {
                for s in mrf.graph.slots(j as usize) {
                    let k = mrf.graph.adj_out[s];
                    if k == rev {
                        continue;
                    }
                    let r = la.refresh(mrf, msgs, k, &mut gather);
                    c.refreshes += 1;
                    if r >= eps {
                        heap.update(k, r);
                        c.inserts += 1;
                    } else {
                        heap.remove(k);
                    }
                }
            }
            if c.updates % OBSERVE_EVERY == 0 {
                if let (Some(obs), Some(t)) = (observer, tick) {
                    let now = timer.elapsed_secs();
                    if now - last_sample >= t {
                        last_sample = now;
                        obs.sample(now, &c, heap.peek().map_or(0.0, |(_, p)| p));
                    }
                }
            }
            if c.updates % 1024 == 0 && budget.expired(c.updates) {
                converged = false;
                break;
            }
        }

        let final_max = la.max_residual();
        if let Some(obs) = observer {
            obs.sample(timer.elapsed_secs(), &c, final_max);
        }
        Ok(EngineStats {
            converged: converged && final_max < eps,
            wall_secs: timer.elapsed_secs(),
            metrics: MetricsReport::aggregate(&[c]),
            final_max_priority: final_max,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bp::{all_marginals, exact_marginals, max_marginal_diff};
    use crate::configio::{AlgorithmSpec, ModelSpec};
    use crate::model::builders;

    fn run_on(spec: ModelSpec, seed: u64) -> (Mrf, Messages, EngineStats) {
        let mrf = builders::build(&spec, seed);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(spec, AlgorithmSpec::SequentialResidual).with_seed(seed);
        let stats = SequentialResidual.run(&mrf, &msgs, &cfg).unwrap();
        (mrf, msgs, stats)
    }

    #[test]
    fn tree_converges_with_minimum_updates() {
        // Tree with root evidence: exactly n−1 useful updates (the edges
        // pointing away from the root), per §4.
        let (_, _, stats) = run_on(ModelSpec::Tree { n: 127 }, 1);
        assert!(stats.converged);
        assert_eq!(stats.metrics.total.useful_updates, 126);
        assert_eq!(stats.metrics.total.updates, 126);
    }

    #[test]
    fn tree_marginals_exact() {
        let (mrf, msgs, stats) = run_on(ModelSpec::Tree { n: 15 }, 1);
        assert!(stats.converged);
        let bp = all_marginals(&mrf, &msgs);
        let exact = exact_marginals(&mrf, 1 << 20).unwrap();
        assert!(max_marginal_diff(&bp, &exact) < 1e-6);
    }

    #[test]
    fn ising_converges_close_to_exact() {
        let (mrf, msgs, stats) = run_on(ModelSpec::Ising { n: 3 }, 3);
        assert!(stats.converged);
        assert!(stats.final_max_priority < 1e-5);
        let bp = all_marginals(&mrf, &msgs);
        let exact = exact_marginals(&mrf, 1 << 20).unwrap();
        // Loopy BP is approximate; 3×3 grids are mild.
        assert!(max_marginal_diff(&bp, &exact) < 0.05);
    }

    #[test]
    fn deterministic_update_count() {
        let (_, _, s1) = run_on(ModelSpec::Ising { n: 8 }, 5);
        let (_, _, s2) = run_on(ModelSpec::Ising { n: 8 }, 5);
        assert_eq!(s1.metrics.total.updates, s2.metrics.total.updates);
    }

    #[test]
    fn budget_stops_run() {
        let mrf = builders::build(&ModelSpec::Ising { n: 10 }, 1);
        let msgs = Messages::uniform(&mrf);
        let cfg = RunConfig::new(ModelSpec::Ising { n: 10 }, AlgorithmSpec::SequentialResidual)
            .with_max_updates(50);
        let stats = SequentialResidual.run(&mrf, &msgs, &cfg).unwrap();
        assert!(!stats.converged);
        assert!(stats.metrics.total.updates <= 1024 + 50);
    }

    #[test]
    fn ldpc_decodes() {
        let inst = builders::ldpc::build(240, 0.04, 7);
        let msgs = Messages::uniform(&inst.mrf);
        let cfg = RunConfig::new(
            ModelSpec::Ldpc { n: 240, flip_prob: 0.04 },
            AlgorithmSpec::SequentialResidual,
        );
        let stats = SequentialResidual.run(&inst.mrf, &msgs, &cfg).unwrap();
        assert!(stats.converged);
        let bits = crate::bp::decode_bits(&inst.mrf, &msgs, inst.num_vars);
        assert_eq!(bits, inst.sent, "decoded to the transmitted codeword");
    }
}
