//! `cargo bench --bench microbench` — component-level benchmarks feeding
//! the §Perf analysis in EXPERIMENTS.md: scheduler op throughput, message
//! update rate per model family, the update-kernel axes (edgewise vs fused
//! refresh shape, scalar vs SIMD data path), lookahead refresh cost, the
//! cold path (CSR build, model save/load, message init), and PJRT call
//! overhead (when artifacts exist). Each group reports markdown to stdout
//! and CSV + JSON under `results/bench/`; full end-to-end sweeps with
//! convergence traces are `relaxed-bp bench` (see the `telemetry` module).
//!
//! `--quick` shrinks sizes for CI smoke; `--only GROUP` runs one group
//! (e.g. `--only model_prep` for the cold-path floors in CI).

use relaxed_bp::benchlib::{BenchConfig, BenchGroup};
use relaxed_bp::bp::{
    compute_message_with, fused_node_refresh, msg_buf, Kernel, Lookahead, Messages, MsgScratch,
    NodeScratch, Precision,
};
use relaxed_bp::configio::ModelSpec;
use relaxed_bp::engines::batched::{BatchCompute, NativeBatch};
use relaxed_bp::model::{builders, io as model_io, FactorPool, GraphBuilder, Mrf, NodeFactors};
use relaxed_bp::runtime::{artifacts_dir, batch::PjrtBatch};
use relaxed_bp::sched::{Entry, ExactQueue, Multiqueue, RandomQueues, Scheduler};
use relaxed_bp::util::Xoshiro256;

/// `--quick` = the CI smoke configuration: fewer samples / ops, tight
/// budget, same coverage.
fn quick() -> bool {
    std::env::args().any(|a| a == "--quick")
}

/// `--only GROUP` = run a single benchmark group.
fn only() -> Option<String> {
    let mut args = std::env::args();
    while let Some(a) = args.next() {
        if a == "--only" {
            return args.next();
        }
    }
    None
}

fn cfg() -> BenchConfig {
    if quick() {
        BenchConfig { warmup: 1, samples: 2, budget_secs: 5.0, verbose: true }
    } else {
        BenchConfig { warmup: 1, samples: 5, budget_secs: 30.0, verbose: true }
    }
}

fn bench_scheduler(g: &mut BenchGroup, name: &str, q: &dyn Scheduler) {
    let ops: u32 = if quick() { 20_000 } else { 200_000 };
    g.bench(&format!("{name}/insert_pop_{ops}"), || {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for t in 0..ops {
            q.insert(Entry { prio: rng.next_f64(), task: t, epoch: 0 }, &mut rng);
        }
        let mut popped = 0u32;
        while q.pop(&mut rng).is_some() {
            popped += 1;
        }
        assert_eq!(popped, ops);
        (2 * ops) as f64
    });
}

/// Star MRF: one center of degree `deg`, every node with domain `dom`,
/// pseudo-random positive factors — the isolated unit of the fused-kernel
/// comparison (a node touch refreshes the center's whole out-set).
fn star_mrf(deg: usize, dom: usize, seed: u64) -> Mrf {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut gb = GraphBuilder::new(deg + 1);
    for leaf in 1..=deg {
        gb.add_edge(0, leaf);
    }
    let g = gb.build();
    let mut pool = FactorPool::new();
    let mut factors = Vec::with_capacity(deg);
    for _ in 0..deg {
        let vals: Vec<f64> = (0..dom * dom).map(|_| rng.uniform(0.1, 1.0)).collect();
        factors.push(pool.add(dom, dom, &vals));
    }
    let node_factors: Vec<Vec<f64>> = (0..=deg)
        .map(|_| (0..dom).map(|_| rng.uniform(0.1, 1.0)).collect())
        .collect();
    Mrf::assemble(
        "star",
        g,
        vec![dom as u32; deg + 1],
        NodeFactors::from_vecs(&node_factors),
        factors,
        pool,
    )
}

/// Update kernel: edge-wise fan-out vs fused node refresh, with the
/// scalar-vs-SIMD data path on the fused shape. One "node touch" =
/// recompute every out-message of the center node. Edge-wise pays one full
/// gather per out-edge (O(deg²) message reads); fused pays one
/// prefix/suffix pass (O(deg)).
fn group_update_kernel() {
    let mut g = BenchGroup::new("update_kernel").with_config(cfg());
    let reps: usize = if quick() { 50 } else { 500 };
    for &deg in &[2usize, 8, 64] {
        for &dom in &[2usize, 8, 32] {
            let mrf = star_mrf(deg, dom, 42);
            let msgs = Messages::uniform(&mrf);
            let la = Lookahead::init(&mrf, &msgs, Kernel::Scalar);
            let mut gather = MsgScratch::new();
            g.bench(&format!("edgewise/deg{deg}_dom{dom}"), || {
                for _ in 0..reps {
                    for s in mrf.graph.slots(0) {
                        la.refresh(&mrf, &msgs, mrf.graph.adj_out[s], &mut gather);
                    }
                }
                (reps * deg) as f64
            });
            for kernel in [Kernel::Scalar, Kernel::Simd] {
                let la = Lookahead::init(&mrf, &msgs, kernel);
                let mut sc = NodeScratch::new();
                let mut batch: Vec<(u32, f64)> = Vec::with_capacity(deg);
                g.bench(&format!("fused_{}/deg{deg}_dom{dom}", kernel.label()), || {
                    for _ in 0..reps {
                        batch.clear();
                        la.refresh_node(&mrf, &msgs, 0, None, &mut sc, &mut batch);
                    }
                    (reps * deg) as f64
                });
            }
            // Raw kernel (no lookahead store): isolates the compute.
            let mut sc2 = NodeScratch::new();
            g.bench(&format!("fused_kernel_only/deg{deg}_dom{dom}"), || {
                let mut sink = 0.0f64;
                for _ in 0..reps {
                    fused_node_refresh(&mrf, &msgs, 0, None, &mut sc2, Kernel::Simd, |_, vals, _| {
                        sink += vals[0];
                    });
                }
                assert!(sink.is_finite());
                (reps * deg) as f64
            });
        }
    }
    g.report();
}

/// SIMD kernel group: scalar vs simd full sweeps on the wide-domain
/// families (the data-path axis in isolation).
fn group_simd_kernel() {
    let mut g = BenchGroup::new("simd_kernel").with_config(cfg());
    for spec in [
        ModelSpec::Ldpc { n: if quick() { 120 } else { 3_000 }, flip_prob: 0.07 },
        ModelSpec::Potts { n: if quick() { 8 } else { 40 }, q: 32 },
        ModelSpec::Ising { n: if quick() { 16 } else { 100 } },
    ] {
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let me = mrf.num_messages() as u32;
        for kernel in [Kernel::Scalar, Kernel::Simd] {
            let mut out = msg_buf();
            let mut gather = MsgScratch::new();
            g.bench(&format!("{}/{}_sweep_{me}", spec.name(), kernel.label()), || {
                for e in 0..me {
                    compute_message_with(&mrf, &msgs, e, &mut out, &mut gather, kernel);
                }
                me as f64
            });
        }
    }
    g.report();
}

/// Storage precision: f64 vs f32 arenas under the full
/// read→compute→write cycle (gathers widen, stores round; the compute in
/// between is identical f64 either way, so the delta is pure memory-path).
fn group_precision() {
    let mut g = BenchGroup::new("precision").with_config(cfg());
    for spec in [
        ModelSpec::Ldpc { n: if quick() { 120 } else { 3_000 }, flip_prob: 0.07 },
        ModelSpec::Potts { n: if quick() { 8 } else { 40 }, q: 32 },
        ModelSpec::Ising { n: if quick() { 16 } else { 100 } },
    ] {
        let mrf = builders::build(&spec, 1);
        let me = mrf.num_messages() as u32;
        for precision in [Precision::F64, Precision::F32] {
            let msgs = Messages::uniform_with(&mrf, precision);
            let mut out = msg_buf();
            let mut gather = MsgScratch::new();
            g.bench(&format!("{}/{}_rw_sweep_{me}", spec.name(), precision.label()), || {
                for e in 0..me {
                    let len =
                        compute_message_with(&mrf, &msgs, e, &mut out, &mut gather, Kernel::Simd);
                    msgs.write_msg_bulk(&mrf, e, &out[..len]);
                }
                me as f64
            });
        }
    }
    g.report();
}

/// Scheduler ops.
fn group_schedulers() {
    let mut g = BenchGroup::new("schedulers").with_config(cfg());
    bench_scheduler(&mut g, "exact", &ExactQueue::new());
    bench_scheduler(&mut g, "multiqueue_8", &Multiqueue::new(8));
    bench_scheduler(&mut g, "multiqueue_32", &Multiqueue::new(32));
    bench_scheduler(&mut g, "random_queues_8", &RandomQueues::new(8));
    g.report();
}

/// Message update kernel (native) per model family.
fn group_message_update() {
    let mut g = BenchGroup::new("message_update").with_config(cfg());
    for spec in [
        ModelSpec::Tree { n: 10_000 },
        ModelSpec::Ising { n: 100 },
        ModelSpec::Ldpc { n: 3_000, flip_prob: 0.07 },
    ] {
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let me = mrf.num_messages() as u32;
        g.bench(&format!("{}/full_sweep_{me}", spec.name()), || {
            let mut out = msg_buf();
            let mut gather = MsgScratch::new();
            for e in 0..me {
                compute_message_with(&mrf, &msgs, e, &mut out, &mut gather, Kernel::Simd);
            }
            me as f64
        });
    }
    g.report();
}

/// Lookahead refresh + commit cycle.
fn group_lookahead() {
    let mut g = BenchGroup::new("lookahead").with_config(cfg());
    let mrf = builders::build(&ModelSpec::Ising { n: 100 }, 1);
    let msgs = Messages::uniform(&mrf);
    let la = Lookahead::init(&mrf, &msgs, Kernel::Simd);
    let me = mrf.num_messages() as u32;
    let mut gather = MsgScratch::new();
    g.bench("ising100/refresh_sweep", || {
        for e in 0..me {
            la.refresh(&mrf, &msgs, e, &mut gather);
        }
        me as f64
    });
    g.report();
}

/// Batched backends: native (scalar + simd) vs PJRT.
fn group_batched_backends() {
    let mut g = BenchGroup::new("batched_backends").with_config(cfg());
    let mrf = builders::build(&ModelSpec::Ising { n: 64 }, 1);
    let msgs = Messages::uniform(&mrf);
    let edges: Vec<u32> = (0..1024u32).collect();
    let stride = mrf.max_domain();
    let mut out = vec![0.0; edges.len() * stride];
    let mut res = vec![0.0; edges.len()];
    for kernel in [Kernel::Scalar, Kernel::Simd] {
        let native = NativeBatch { kernel };
        g.bench(&format!("native_{}/1024", kernel.label()), || {
            native.compute_batch(&mrf, &msgs, &edges, &mut out, &mut res);
            edges.len() as f64
        });
    }
    if artifacts_dir().join("batched_update_1024.hlo.txt").exists() {
        let pjrt = PjrtBatch::load_default(1024).expect("artifact");
        g.bench("pjrt/1024", || {
            pjrt.compute_batch(&mrf, &msgs, &edges, &mut out, &mut res);
            edges.len() as f64
        });
    } else {
        eprintln!("[microbench] skipping PJRT backend (run `make artifacts`)");
    }
    g.report();
}

/// Deterministic "ring + chords" edge stream: node `i` connects to `i+1`
/// and `i+7` (mod `n`) — duplicate-free and self-loop-free for the sizes
/// used here, isolating CSR counting-sort throughput from RNG and factor
/// construction.
fn stream_edges(gb: &mut GraphBuilder, n: usize) {
    for i in 0..n {
        gb.add_edge(i, (i + 1) % n);
        gb.add_edge(i, (i + 7) % n);
    }
}

/// Cold path: CSR construction (serial vs 8-thread counting sort on the
/// same edge stream — bit-identical outputs, see `model::graph` tests),
/// full model build, v1-vs-v2 snapshot save/load, and message-state init.
/// CI's large-model smoke job runs `--only model_prep` and gates on the
/// serial-vs-parallel build and v1-vs-v2 load ratios.
fn group_model_prep() {
    let mut g = BenchGroup::new("model_prep").with_config(cfg());
    let n: usize = if quick() { 100_000 } else { 1_000_000 };
    for &threads in &[1usize, 8] {
        g.bench(&format!("csr_build/threads{threads}"), || {
            let mut gb = GraphBuilder::with_edge_capacity(n, 2 * n);
            stream_edges(&mut gb, n);
            let csr = gb.build_with_threads(threads);
            csr.num_directed_edges() as f64
        });
    }

    let spec = ModelSpec::PowerLaw { n: if quick() { 50_000 } else { 500_000 }, m: 2 };
    g.bench("powerlaw/full_build", || {
        let mrf = builders::build(&spec, 42);
        mrf.num_messages() as f64
    });

    // Snapshot I/O: v1 (streamed, serial) vs v2 (sectioned bulk writes,
    // parallel chunked loads) on the same instance.
    let mrf = builders::build(&spec, 42);
    let dir = std::env::temp_dir();
    let p1 = dir.join("rbp_model_prep_v1.rbpm");
    let p2 = dir.join("rbp_model_prep_v2.rbpm");
    let (s1, s2) = (p1.to_string_lossy().into_owned(), p2.to_string_lossy().into_owned());
    g.bench("save/v1", || model_io::save_v1(&mrf, &s1).expect("save v1") as f64);
    g.bench("save/v2", || model_io::save(&mrf, &s2).expect("save v2") as f64);
    g.bench("load/v1", || model_io::load(&s1).expect("load v1").num_messages() as f64);
    for &threads in &[1usize, 8] {
        g.bench(&format!("load/v2_threads{threads}"), || {
            model_io::load_with_threads(&s2, threads).expect("load v2").num_messages() as f64
        });
    }
    let _ = std::fs::remove_file(&p1);
    let _ = std::fs::remove_file(&p2);

    g.bench("messages/uniform_init", || {
        let msgs = Messages::uniform(&mrf);
        drop(msgs);
        mrf.num_messages() as f64
    });
    g.report();
}

/// Out-of-core load axis: the zero-copy mmap load (verify off and on)
/// vs the copying read path at 1 and 8 threads, on the same generated
/// v2 file, plus mem-vs-mmap message-arena init. CI's out-of-core smoke
/// job runs `--only mmap_load` and gates on the map-vs-read ratio.
fn group_mmap_load() {
    use relaxed_bp::bp::ArenaMode;
    use relaxed_bp::model::io::LoadMode;
    let mut g = BenchGroup::new("mmap_load").with_config(cfg());
    let spec = ModelSpec::PowerLaw { n: if quick() { 50_000 } else { 500_000 }, m: 2 };
    let mrf = builders::build(&spec, 42);
    let p = std::env::temp_dir().join("rbp_mmap_load_v2.rbpm");
    let s = p.to_string_lossy().into_owned();
    model_io::save(&mrf, &s).expect("save v2");

    g.bench("load/map", || {
        let (m, mode) = model_io::load_with_mode(&s, 8, LoadMode::Map, false).expect("map load");
        assert!(!cfg!(unix) || mode == LoadMode::Map, "map load fell back on unix");
        m.num_messages() as f64
    });
    g.bench("load/map_verified", || {
        let (m, _) = model_io::load_with_mode(&s, 8, LoadMode::Map, true).expect("map load");
        m.num_messages() as f64
    });
    for &threads in &[1usize, 8] {
        g.bench(&format!("load/read_threads{threads}"), || {
            let (m, _) =
                model_io::load_with_mode(&s, threads, LoadMode::Read, true).expect("read load");
            m.num_messages() as f64
        });
    }
    let _ = std::fs::remove_file(&p);

    g.bench("arena/uniform_init_mem", || {
        let msgs = Messages::uniform_in(&mrf, Precision::F64, &ArenaMode::Mem).expect("mem arena");
        drop(msgs);
        mrf.num_messages() as f64
    });
    if cfg!(unix) {
        g.bench("arena/uniform_init_mmap", || {
            let msgs = Messages::uniform_in(&mrf, Precision::F64, &ArenaMode::Mmap { dir: None })
                .expect("mmap arena");
            drop(msgs);
            mrf.num_messages() as f64
        });
    }
    g.report();
}

fn main() {
    let groups: &[(&str, fn())] = &[
        ("update_kernel", group_update_kernel),
        ("simd_kernel", group_simd_kernel),
        ("precision", group_precision),
        ("schedulers", group_schedulers),
        ("message_update", group_message_update),
        ("lookahead", group_lookahead),
        ("batched_backends", group_batched_backends),
        ("model_prep", group_model_prep),
        ("mmap_load", group_mmap_load),
    ];
    let only = only();
    for (name, run) in groups {
        let selected = match only.as_deref() {
            None => true,
            Some(o) => o == *name,
        };
        if selected {
            run();
        }
    }
    if let Some(o) = only {
        if !groups.iter().any(|(name, _)| *name == o) {
            eprintln!("[microbench] unknown group '{o}'; available:");
            for (name, _) in groups {
                eprintln!("  {name}");
            }
            std::process::exit(2);
        }
    }
}
