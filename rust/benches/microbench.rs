//! `cargo bench --bench microbench` — component-level benchmarks feeding
//! the §Perf analysis in EXPERIMENTS.md: scheduler op throughput, message
//! update rate per model family, lookahead refresh cost, and PJRT call
//! overhead (when artifacts exist). Each group reports markdown to stdout
//! and CSV + JSON under `results/bench/`; full end-to-end sweeps with
//! convergence traces are `relaxed-bp bench` (see the `telemetry` module).

use relaxed_bp::benchlib::{BenchConfig, BenchGroup};
use relaxed_bp::bp::{compute_message, msg_buf, Lookahead, Messages};
use relaxed_bp::configio::ModelSpec;
use relaxed_bp::engines::batched::{BatchCompute, NativeBatch};
use relaxed_bp::model::builders;
use relaxed_bp::runtime::{artifacts_dir, batch::PjrtBatch};
use relaxed_bp::sched::{Entry, ExactQueue, Multiqueue, RandomQueues, Scheduler};
use relaxed_bp::util::Xoshiro256;

fn cfg() -> BenchConfig {
    BenchConfig { warmup: 1, samples: 5, budget_secs: 30.0, verbose: true }
}

fn bench_scheduler(g: &mut BenchGroup, name: &str, q: &dyn Scheduler) {
    let ops = 200_000u32;
    g.bench(&format!("{name}/insert_pop_{ops}"), || {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for t in 0..ops {
            q.insert(Entry { prio: rng.next_f64(), task: t, epoch: 0 }, &mut rng);
        }
        let mut popped = 0u32;
        while q.pop(&mut rng).is_some() {
            popped += 1;
        }
        assert_eq!(popped, ops);
        (2 * ops) as f64
    });
}

fn main() {
    // ---- Scheduler ops ----
    let mut g = BenchGroup::new("schedulers").with_config(cfg());
    bench_scheduler(&mut g, "exact", &ExactQueue::new());
    bench_scheduler(&mut g, "multiqueue_8", &Multiqueue::new(8));
    bench_scheduler(&mut g, "multiqueue_32", &Multiqueue::new(32));
    bench_scheduler(&mut g, "random_queues_8", &RandomQueues::new(8));
    g.report();

    // ---- Message update kernel (native) per model family ----
    let mut g = BenchGroup::new("message_update").with_config(cfg());
    for spec in [
        ModelSpec::Tree { n: 10_000 },
        ModelSpec::Ising { n: 100 },
        ModelSpec::Ldpc { n: 3_000, flip_prob: 0.07 },
    ] {
        let mrf = builders::build(&spec, 1);
        let msgs = Messages::uniform(&mrf);
        let me = mrf.num_messages() as u32;
        g.bench(&format!("{}/full_sweep_{me}", spec.name()), || {
            let mut out = msg_buf();
            for e in 0..me {
                compute_message(&mrf, &msgs, e, &mut out);
            }
            me as f64
        });
    }
    g.report();

    // ---- Lookahead refresh + commit cycle ----
    let mut g = BenchGroup::new("lookahead").with_config(cfg());
    let mrf = builders::build(&ModelSpec::Ising { n: 100 }, 1);
    let msgs = Messages::uniform(&mrf);
    let la = Lookahead::init(&mrf, &msgs);
    let me = mrf.num_messages() as u32;
    g.bench("ising100/refresh_sweep", || {
        for e in 0..me {
            la.refresh(&mrf, &msgs, e);
        }
        me as f64
    });
    g.report();

    // ---- Batched backends: native vs PJRT ----
    let mut g = BenchGroup::new("batched_backends").with_config(cfg());
    let mrf = builders::build(&ModelSpec::Ising { n: 64 }, 1);
    let msgs = Messages::uniform(&mrf);
    let edges: Vec<u32> = (0..1024u32).collect();
    let stride = mrf.max_domain();
    let mut out = vec![0.0; edges.len() * stride];
    let mut res = vec![0.0; edges.len()];
    g.bench("native/1024", || {
        NativeBatch.compute_batch(&mrf, &msgs, &edges, &mut out, &mut res);
        edges.len() as f64
    });
    if artifacts_dir().join("batched_update_1024.hlo.txt").exists() {
        let pjrt = PjrtBatch::load_default(1024).expect("artifact");
        g.bench("pjrt/1024", || {
            pjrt.compute_batch(&mrf, &msgs, &edges, &mut out, &mut res);
            edges.len() as f64
        });
    } else {
        eprintln!("[microbench] skipping PJRT backend (run `make artifacts`)");
    }
    g.report();
}
