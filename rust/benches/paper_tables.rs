//! `cargo bench --bench paper_tables` — regenerates the paper's tables at
//! benchmark scale (one BenchGroup per table). The full-size reproduction
//! lives in `relaxed-bp experiment …`; these benches give quick,
//! statistically summarized signals per table with the in-repo benchlib
//! (criterion is unavailable offline).
//!
//! Scale via env: RBP_BENCH_SCALE (default 0.01 = 1% of paper-small sizes),
//! RBP_BENCH_SAMPLES, RBP_BENCH_BUDGET.

use relaxed_bp::benchlib::BenchGroup;
use relaxed_bp::configio::{AlgorithmSpec, ModelSpec, RunConfig};
use relaxed_bp::harness::Harness;
use relaxed_bp::model::builders;
use relaxed_bp::run::run_on_model;

fn harness() -> Harness {
    let scale = std::env::var("RBP_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.01);
    Harness { scale, threads: vec![1, 2, 4], max_threads: 4, ..Harness::default() }
}

fn bench_cell(g: &mut BenchGroup, h: &Harness, spec: &ModelSpec, alg: AlgorithmSpec, p: usize) {
    let mrf = builders::build(spec, h.seed);
    let name = format!("{}/{}/p{}", spec.name(), alg.name(), p);
    g.bench(&name, || {
        let cfg = RunConfig::new(spec.clone(), alg.clone())
            .with_threads(p)
            .with_seed(h.seed);
        let rep = run_on_model(&cfg, mrf.clone()).expect("run");
        rep.stats.metrics.total.updates as f64
    });
}

fn main() {
    let h = harness();

    // Table 1 / 5: speedups vs sequential residual at max threads.
    let mut t1 = BenchGroup::new("table1_speedups");
    for spec in h.models() {
        bench_cell(&mut t1, &h, &spec, AlgorithmSpec::SequentialResidual, 1);
        for alg in [
            AlgorithmSpec::Synchronous,
            AlgorithmSpec::CoarseGrained,
            AlgorithmSpec::Splash { h: 10 },
            AlgorithmSpec::RandomSplash { h: 2 },
            AlgorithmSpec::RelaxedResidual,
            AlgorithmSpec::WeightDecay,
            AlgorithmSpec::Priority,
            AlgorithmSpec::RelaxedSmartSplash { h: 2 },
        ] {
            bench_cell(&mut t1, &h, &spec, alg, h.max_threads);
        }
    }
    t1.report();

    // Table 2 / 6 uses the same runs; the metric column above (updates)
    // is that table's content. Table 3: relaxed vs exact across threads.
    let mut t3 = BenchGroup::new("table3_relaxation_overhead");
    for spec in h.models() {
        bench_cell(&mut t3, &h, &spec, AlgorithmSpec::SequentialResidual, 1);
        for &p in &h.threads {
            bench_cell(&mut t3, &h, &spec, AlgorithmSpec::RelaxedResidual, p);
        }
    }
    t3.report();

    // Table 4: relaxed residual vs the best non-relaxed alternative.
    let mut t4 = BenchGroup::new("table4_vs_best_nonrelaxed");
    for spec in h.models() {
        for &p in &h.threads {
            bench_cell(&mut t4, &h, &spec, AlgorithmSpec::RelaxedResidual, p);
            bench_cell(&mut t4, &h, &spec, AlgorithmSpec::Synchronous, p);
            bench_cell(&mut t4, &h, &spec, AlgorithmSpec::Splash { h: 2 }, p);
        }
    }
    t4.report();

    // Table 7: randomized synchronous.
    let mut t7 = BenchGroup::new("table7_random_synch");
    for spec in h.models() {
        bench_cell(&mut t7, &h, &spec, AlgorithmSpec::Synchronous, h.max_threads);
        bench_cell(&mut t7, &h, &spec, AlgorithmSpec::RelaxedResidual, 1);
        for low_p in [0.1, 0.4, 0.7] {
            bench_cell(
                &mut t7,
                &h,
                &spec,
                AlgorithmSpec::RandomSynchronous { low_p },
                h.max_threads,
            );
        }
    }
    t7.report();
}
