//! `cargo bench --bench paper_figures` — the scaling-figure series
//! (Figures 2, 4–7) at benchmark scale, plus the Lemma 2 / Claim 4 tree
//! experiments. Full-size: `relaxed-bp experiment fig4 …`.

use relaxed_bp::benchlib::BenchGroup;
use relaxed_bp::configio::{AlgorithmSpec, ModelSpec, RunConfig};
use relaxed_bp::harness::Harness;
use relaxed_bp::model::builders;
use relaxed_bp::run::run_on_model;

fn harness() -> Harness {
    let scale = std::env::var("RBP_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.01);
    Harness { scale, threads: vec![1, 2, 4], max_threads: 4, ..Harness::default() }
}

fn series(g: &mut BenchGroup, h: &Harness, spec: &ModelSpec, algs: &[AlgorithmSpec]) {
    let mrf = builders::build(spec, h.seed);
    for alg in algs {
        for &p in &h.threads {
            let name = format!("{}/{}/p{}", spec.name(), alg.name(), p);
            let mrf = mrf.clone();
            let spec = spec.clone();
            let alg = alg.clone();
            let seed = h.seed;
            g.bench(&name, move || {
                let cfg = RunConfig::new(spec.clone(), alg.clone())
                    .with_threads(p)
                    .with_seed(seed);
                run_on_model(&cfg, mrf.clone()).expect("run").stats.metrics.total.updates as f64
            });
        }
    }
}

fn main() {
    let h = harness();
    let models = h.models();

    // Figure 2: Ising, three algorithms.
    let mut f2 = BenchGroup::new("fig2_ising_headline");
    series(
        &mut f2,
        &h,
        &models[1],
        &[
            AlgorithmSpec::Synchronous,
            AlgorithmSpec::Splash { h: 10 },
            AlgorithmSpec::RelaxedResidual,
        ],
    );
    f2.report();

    // Figures 4–7: scaling roster per model.
    let roster = [
        AlgorithmSpec::Synchronous,
        AlgorithmSpec::CoarseGrained,
        AlgorithmSpec::RelaxedResidual,
        AlgorithmSpec::WeightDecay,
        AlgorithmSpec::RelaxedSmartSplash { h: 2 },
    ];
    for (fig, spec) in [("fig4_tree", &models[0]), ("fig5_ising", &models[1]),
                        ("fig6_potts", &models[2]), ("fig7_ldpc", &models[3])] {
        let mut g = BenchGroup::new(fig);
        series(&mut g, &h, spec, &roster);
        g.report();
    }

    // Lemma 2 / Claim 4: relaxation overhead on analytic tree instances.
    let mut l2 = BenchGroup::new("lemma2_tree_overhead");
    let n = 20_000;
    for spec in [
        ModelSpec::UniformTree { n, arity: 2 },
        ModelSpec::Path { n: n / 10 },
        ModelSpec::AdversarialTree { n },
    ] {
        series(
            &mut l2,
            &h,
            &spec,
            &[AlgorithmSpec::RelaxedResidual, AlgorithmSpec::RelaxedOptimalTree],
        );
    }
    l2.report();
}
